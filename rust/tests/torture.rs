//! Adversarial-network torture suite: the seeded deterministic
//! adversary transport (`net::adversary`) against the hardened
//! protocol — duplicate/reorder/partition profiles across every FT
//! mechanism, handshake attrition against the CONNECT retry loop,
//! data-stream cuts against the failover path, torture composed with
//! kill-point fault plans, and the serve watchdog. Throughout: the sink
//! dataset must land byte-exact, every object must be written exactly
//! once (the (fid, block) write ledger absorbs duplicates), and resumes
//! must honor the log-based retransmit bound `resent <= total - logged`.
//!
//! The off-switch is pinned too: with the adversary disarmed (seed 0)
//! and the hardening knobs at ANY value, the wire bytes are identical
//! to a run without this subsystem.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use ftlads::config::{Config, TortureSpec};
use ftlads::coordinator::serve::{JobRequest, Serve};
use ftlads::coordinator::sink::SinkSession;
use ftlads::coordinator::source::SourceSession;
use ftlads::coordinator::{SimEnv, TransferJob, TransferOutcome, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{recover, Mechanism, Method};
use ftlads::net::adversary::AdversaryEndpoint;
use ftlads::net::{channel, Endpoint, FaultController, Message, NetError, Side};
use ftlads::pfs::Pfs;
use ftlads::workload;

/// Endpoint tap recording the encoded bytes of every send that passes
/// through it. Placed UNDER an [`AdversaryEndpoint`] it records exactly
/// what the adversary emitted (duplicates included); used bare it
/// records what a session put on the wire.
struct ByteTap {
    inner: Arc<dyn Endpoint>,
    sent: Arc<Mutex<Vec<Vec<u8>>>>,
}

impl ByteTap {
    fn new(inner: Arc<dyn Endpoint>) -> (ByteTap, Arc<Mutex<Vec<Vec<u8>>>>) {
        let sent = Arc::new(Mutex::new(Vec::new()));
        (ByteTap { inner, sent: sent.clone() }, sent)
    }
}

impl Endpoint for ByteTap {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        let mut bytes = Vec::new();
        msg.encode(&mut bytes);
        self.sent.lock().unwrap_or_else(|e| e.into_inner()).push(bytes);
        self.inner.send(msg)
    }

    fn recv(&self) -> Result<Message, NetError> {
        self.inner.recv()
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        self.inner.recv_timeout(timeout)
    }

    fn payload_sent(&self) -> u64 {
        self.inner.payload_sent()
    }
}

/// Sorted copy — IO threads race, so cross-run wire comparison is by
/// multiset (the same convention as the other byte-identity pins).
fn sorted(trace: &Arc<Mutex<Vec<Vec<u8>>>>) -> Vec<Vec<u8>> {
    let mut t = trace.lock().unwrap_or_else(|e| e.into_inner()).clone();
    t.sort();
    t
}

/// Run one fused (K = 1) session over tapped channel endpoints, with an
/// optional torture wrapper over each tap, returning both sides' frame
/// traces.
fn tapped_session(
    cfg: &Config,
    env: &SimEnv,
    torture: Option<&TortureSpec>,
) -> (Arc<Mutex<Vec<Vec<u8>>>>, Arc<Mutex<Vec<Vec<u8>>>>) {
    let (src_ep, snk_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
    let (src_tap, src_sent) = ByteTap::new(Arc::new(src_ep));
    let (snk_tap, snk_sent) = ByteTap::new(Arc::new(snk_ep));
    let wrap = |tap: ByteTap, side: Side| -> Arc<dyn Endpoint> {
        match torture {
            Some(spec) => {
                Arc::new(AdversaryEndpoint::new(Arc::new(tap), spec.clone(), side, None))
            }
            None => Arc::new(tap),
        }
    };
    let node = SinkSession::new(cfg, env.sink.clone(), wrap(snk_tap, Side::Sink))
        .spawn()
        .unwrap();
    let spec = TransferSpec::fresh(env.files.clone());
    let src = SourceSession::new(cfg, env.source.clone(), wrap(src_tap, Side::Source))
        .run(&spec)
        .unwrap();
    assert!(src.fault.is_none(), "{:?}", src.fault);
    let snk = node.join();
    assert!(snk.fault.is_none(), "{:?}", snk.fault);
    env.verify_sink_complete().unwrap();
    (src_sent, snk_sent)
}

#[test]
fn torture_off_is_byte_identical_to_baseline() {
    // The off-switch pin, two layers deep: (a) non-default hardening
    // knobs (connect timeout/retries, job deadline) plus a torture
    // profile with the seed at 0 — i.e. disarmed — must put EXACTLY the
    // baseline's bytes on the wire in both directions; (b) a QUIET
    // armed adversary (every probability 0) must be pure pass-through.
    let wl = workload::big_workload(4, 8 * (64 << 10)); // 32 objects

    let base_cfg = Config::for_tests("torture-off-base");
    let base_env = SimEnv::new(base_cfg.clone(), &wl);
    let (base_src, base_snk) = tapped_session(&base_cfg, &base_env, None);

    let mut hard_cfg = Config::for_tests("torture-off-hard");
    hard_cfg.connect_timeout_ms = 1234;
    hard_cfg.connect_retries = 5;
    hard_cfg.job_deadline_ms = 60_000;
    hard_cfg.torture_profile = "dup".into();
    hard_cfg.torture_seed = 0; // disarmed: no adversary is constructed
    assert!(hard_cfg.torture().is_none(), "seed 0 must disarm the profile");
    let hard_env = SimEnv::new(hard_cfg.clone(), &wl);
    let (hard_src, hard_snk) = tapped_session(&hard_cfg, &hard_env, None);

    let quiet_cfg = Config::for_tests("torture-off-quiet");
    let quiet_env = SimEnv::new(quiet_cfg.clone(), &wl);
    let quiet = TortureSpec::quiet(99);
    assert!(quiet.is_quiet());
    let (quiet_src, quiet_snk) = tapped_session(&quiet_cfg, &quiet_env, Some(&quiet));

    for (label, src, snk) in
        [("hardening knobs", &hard_src, &hard_snk), ("quiet adversary", &quiet_src, &quiet_snk)]
    {
        assert_eq!(
            sorted(src),
            sorted(&base_src),
            "{label} changed the source->sink wire bytes"
        );
        assert_eq!(
            sorted(snk),
            sorted(&base_snk),
            "{label} changed the sink->source wire bytes"
        );
    }
    for env in [&base_env, &hard_env, &quiet_env] {
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn torture_profiles_complete_every_ft_mechanism() {
    // The core sweep: {reorder, dup, partition} × every FT mechanism,
    // on the full pipeline shape (windowed issue, batched acks, two
    // data streams). Every combination must complete with a byte-exact
    // sink, every object written exactly once (write_syscalls == total:
    // the (fid, block) ledger dropped every duplicate before the
    // pwrite) and logged exactly once (objects_synced == total: the
    // source's send-window dedup dropped every duplicate ack).
    for (i, profile) in ["reorder", "dup", "partition"].iter().enumerate() {
        for mech in Mechanism::ALL_FT {
            let mut cfg =
                Config::for_tests(&format!("torture-{profile}-{}", mech.as_str()));
            cfg.mechanism = mech;
            cfg.method = Method::Bit64;
            cfg.send_window = 4;
            cfg.ack_batch = 4;
            cfg.ack_flush_us = 500;
            cfg.data_streams = 2;
            cfg.torture_profile = (*profile).into();
            cfg.torture_seed = 0xF7 + i as u64;
            let wl = workload::big_workload(4, 8 * cfg.object_size); // 32 objects
            let total = wl.total_objects(cfg.object_size);
            let env = SimEnv::new(cfg, &wl);
            let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
            assert!(out.completed, "{profile}/{mech:?}: {:?}", out.fault);
            assert_eq!(
                out.source.objects_synced, total,
                "{profile}/{mech:?}: every object exactly once in the send ledger"
            );
            assert_eq!(
                out.sink.write_syscalls, total,
                "{profile}/{mech:?}: duplicate NEW_BLOCK reached a pwrite"
            );
            if *profile == "dup" {
                assert!(
                    out.sink.dup_blocks_dropped > 0,
                    "{mech:?}: dup profile never duplicated a block"
                );
                assert!(
                    out.source.dup_acks_dropped > 0,
                    "{mech:?}: dup profile never duplicated an ack"
                );
            }
            env.verify_sink_complete()
                .unwrap_or_else(|e| panic!("{profile}/{mech:?}: {e}"));
            let left = recover::recover_all(&env.cfg.ft()).unwrap();
            assert!(
                left.is_empty(),
                "{profile}/{mech:?}: logs left after completion"
            );
            let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        }
    }
}

#[test]
fn dup_profile_schedule_is_deterministic_by_seed() {
    // The replayability pin at session level: a lockstep transfer (one
    // IO thread, window 1, batch 1, one file) under the delay-free
    // "dup" profile must emit the IDENTICAL frame sequence — order and
    // bytes — on both sides across two runs with the same seed. The
    // taps sit under the adversary, so duplicated frames are recorded
    // exactly as the wire saw them.
    let spec = TortureSpec::profile("dup", 42).unwrap().unwrap();
    let run = |tag: &str| -> (Vec<Vec<u8>>, u64) {
        let mut cfg = Config::for_tests(tag);
        cfg.io_threads = 1;
        cfg.send_window = 1;
        cfg.ack_batch = 1;
        cfg.data_streams = 1;
        let wl = workload::big_workload(1, 16 * cfg.object_size); // 16 objects
        let env = SimEnv::new(cfg.clone(), &wl);
        let (src_ep, snk_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
        let (src_tap, src_sent) = ByteTap::new(Arc::new(src_ep));
        let (snk_tap, snk_sent) = ByteTap::new(Arc::new(snk_ep));
        let src_adv = Arc::new(AdversaryEndpoint::new(
            Arc::new(src_tap),
            spec.clone(),
            Side::Source,
            None,
        ));
        let snk_adv = Arc::new(AdversaryEndpoint::new(
            Arc::new(snk_tap),
            spec.clone(),
            Side::Sink,
            None,
        ));
        let node = SinkSession::new(&cfg, env.sink.clone(), snk_adv.clone())
            .spawn()
            .unwrap();
        let src = SourceSession::new(&cfg, env.source.clone(), src_adv.clone())
            .run(&TransferSpec::fresh(env.files.clone()))
            .unwrap();
        assert!(src.fault.is_none(), "{:?}", src.fault);
        let snk = node.join();
        assert!(snk.fault.is_none(), "{:?}", snk.fault);
        env.verify_sink_complete().unwrap();
        let duplicated = src_adv.stats().duplicated + snk_adv.stats().duplicated;
        let mut frames = src_sent.lock().unwrap_or_else(|e| e.into_inner()).clone();
        frames.extend(snk_sent.lock().unwrap_or_else(|e| e.into_inner()).clone());
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        (frames, duplicated)
    };
    let (frames_a, dup_a) = run("torture-det-a");
    let (frames_b, dup_b) = run("torture-det-b");
    assert!(dup_a > 0, "the dup profile must actually duplicate something");
    assert_eq!(dup_a, dup_b, "same seed, same duplication schedule");
    assert_eq!(
        frames_a, frames_b,
        "same seed must reproduce the same message schedule"
    );
}

#[test]
fn lossy_handshake_retry_loop_carries_connect() {
    // Handshake attrition: CONNECT / CONNECT_ACK drop 30% of the time.
    // With `connect_retries` armed, each seeded run must either complete
    // (the common case — the backoff loop re-offers the handshake) or
    // fault cleanly and then complete on a disarmed resume. Across the
    // sweep the retry path must demonstrably fire.
    let mut completions = 0u32;
    let mut total_retries = 0u64;
    for seed in 1..=16u64 {
        let mut cfg = Config::for_tests(&format!("torture-lossy-{seed}"));
        cfg.connect_timeout_ms = 40;
        cfg.connect_retries = 6;
        cfg.torture_profile = "lossy-handshake".into();
        cfg.torture_seed = seed;
        let wl = workload::big_workload(2, 4 * cfg.object_size); // 8 objects
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        total_retries += out.source.retries + out.sink.retries;
        if out.completed {
            completions += 1;
        } else {
            // Retries exhausted: the fault must be clean and resumable.
            assert!(out.fault.is_some(), "seed {seed}: incomplete without a fault");
            let mut calm = env.cfg.clone();
            calm.torture_seed = 0;
            let out2 = TransferJob::builder(&calm, &TransferSpec::resuming(env.files.clone()))
                .source_pfs(env.source.clone())
                .sink_pfs(env.sink.clone())
                .run()
                .unwrap();
            assert!(out2.completed, "seed {seed}: resume failed: {:?}", out2.fault);
        }
        env.verify_sink_complete()
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    assert!(
        completions >= 8,
        "lossy handshake must usually be survivable: {completions}/16 completed"
    );
    assert!(total_retries > 0, "16 lossy seeds and the retry path never fired");
}

#[test]
fn cut_stream_fails_over_to_survivors() {
    // The failover drill: at K = 4 the cut-stream profile severs data
    // stream 1 (both directions) mid-transfer. The source must re-home
    // its OST queues onto the three survivors (fresh LPT plan) and
    // finish the job in ONE session — no fault, byte-exact sink, every
    // object written exactly once despite the re-derived in-flight
    // blocks (the write ledger absorbs re-sends).
    let mut cfg = Config::for_tests("torture-cut-k4");
    cfg.mechanism = Mechanism::Universal;
    cfg.method = Method::Bit64;
    cfg.data_streams = 4;
    cfg.send_window = 4;
    cfg.ack_batch = 4;
    cfg.ack_flush_us = 500;
    cfg.torture_profile = "cut-stream".into();
    cfg.torture_seed = 21;
    let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
    let total = wl.total_objects(cfg.object_size);
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "failover did not carry the transfer: {:?}", out.fault);
    assert_eq!(out.data_streams, 4);
    assert_eq!(out.source.objects_synced, total);
    assert_eq!(
        out.sink.write_syscalls, total,
        "failover re-sends must be deduped before the pwrite"
    );
    env.verify_sink_complete().unwrap();
    let left = recover::recover_all(&env.cfg.ft()).unwrap();
    assert!(left.is_empty(), "logs left after completion");
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn torture_composes_with_kill_point_faults() {
    // Torture × the ft_matrix drill: every profile runs under a
    // mid-transfer kill (50% of payload, source side), faults, and
    // resumes — with the adversary STILL armed on the resume. The
    // composed label names both legs, the resume honors the log-based
    // retransmit bound `resent <= total - logged`, and the sink
    // byte-verifies. (cut-stream at K = 2 stacks all three mechanisms:
    // stream death -> failover, kill -> clean fault, resume.)
    for profile in ["reorder", "dup", "partition", "cut-stream"] {
        let mut cfg = Config::for_tests(&format!("torture-kill-{profile}"));
        cfg.mechanism = Mechanism::Universal;
        cfg.method = Method::Bit64;
        cfg.send_window = 4;
        cfg.ack_batch = 4;
        cfg.ack_flush_us = 500;
        cfg.data_streams = 2;
        cfg.torture_profile = profile.into();
        cfg.torture_seed = 0xC0FFEE;
        let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
        let total = wl.total_objects(cfg.object_size);
        let env = SimEnv::new(cfg, &wl);
        let plan = FaultPlan::at_fraction(0.5, Side::Source);
        let label = plan.label_with(Some(profile));
        assert!(label.contains(profile), "composed label must name the profile");
        let out = env
            .run(&TransferSpec::fresh(env.files.clone()).with_fault(plan))
            .unwrap();
        assert!(!out.completed, "{label}: kill point did not fire");
        let logged: u64 = recover::recover_all(&env.cfg.ft())
            .unwrap()
            .values()
            .map(|s| s.count() as u64)
            .sum();
        let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
        assert!(out2.completed, "{label}: resume failed: {:?}", out2.fault);
        assert!(
            out2.source.objects_skipped_resume >= logged,
            "{label}: logged objects not skipped ({} skipped, {logged} logged)",
            out2.source.objects_skipped_resume
        );
        assert!(
            out2.source.objects_sent <= total - logged,
            "{label}: resume retransmitted logged objects \
             ({} sent, {logged} logged of {total})",
            out2.source.objects_sent
        );
        env.verify_sink_complete()
            .unwrap_or_else(|e| panic!("{label}: {e}"));
        let left = recover::recover_all(&env.cfg.ft()).unwrap();
        assert!(left.is_empty(), "{label}: logs left after completion");
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn serve_watchdog_faults_silent_job_and_frees_the_slot() {
    // The per-job deadline: a daemon with one admission slot and a
    // 400 ms deadline gets a job that needs seconds of modeled wire
    // time. The watchdog must fault it (freeing the slot and counting
    // jobs_faulted), and a subsequent fast job must run to completion
    // through the same daemon.
    let mut cfg = Config::for_tests("torture-watchdog");
    cfg.time_scale = 1.0;
    cfg.net_bandwidth = 2e6; // 2 MB/s modeled wire
    cfg.serve_max_jobs = 1;
    cfg.job_deadline_ms = 400;

    let slow_wl = workload::big_workload(4, 16 * cfg.object_size); // 4 MiB ≈ 2 s
    let slow_env = SimEnv::new(cfg.clone(), &slow_wl);
    let serve = Serve::new(cfg.clone());
    let slow = serve
        .submit(
            "tenant",
            1,
            JobRequest {
                spec: TransferSpec::fresh(slow_env.files.clone()),
                source_pfs: slow_env.source.clone() as Arc<dyn Pfs>,
                sink_pfs: slow_env.sink.clone() as Arc<dyn Pfs>,
                runtime: None,
            },
        )
        .unwrap();
    let res = slow.wait();
    assert!(res.is_err(), "watchdog must fault the over-deadline job: {res:?}");
    assert_eq!(serve.stats().jobs_faulted, 1);

    let fast_wl = workload::big_workload(1, cfg.object_size); // 64 KiB ≈ 32 ms
    let fast_env = SimEnv::new(cfg.clone(), &fast_wl);
    let fast = serve
        .submit(
            "tenant",
            1,
            JobRequest {
                spec: TransferSpec::fresh(fast_env.files.clone()),
                source_pfs: fast_env.source.clone() as Arc<dyn Pfs>,
                sink_pfs: fast_env.sink.clone() as Arc<dyn Pfs>,
                runtime: None,
            },
        )
        .unwrap();
    let out: TransferOutcome = fast.wait().unwrap();
    assert!(out.completed, "slot not freed for the next job: {:?}", out.fault);
    let stats = serve.stats();
    assert_eq!(stats.jobs_completed, 1);
    assert_eq!(stats.jobs_faulted, 1);
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}
