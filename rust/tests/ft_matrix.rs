//! Integration matrix: every FT mechanism × method survives a fault at
//! every paper fault point and resumes to a byte-verified sink dataset.
//!
//! This is the correctness core of the reproduction — 3 mechanisms ×
//! 6 methods × 4 fault points (plus edge workloads), each case running
//! the full coordinator (source + sink, all threads) with real logger
//! files on disk.

use ftlads::config::Config;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{recover, Mechanism, Method};
use ftlads::net::Side;
use ftlads::workload;

fn run_matrix_case(mech: Mechanism, method: Method, frac: f64, tag: &str) {
    let mut cfg = Config::for_tests(tag);
    cfg.mechanism = mech;
    cfg.method = method;
    let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
    let env = SimEnv::new(cfg, &wl);

    let out = env
        .run(
            &TransferSpec::fresh(env.files.clone())
                .with_fault(FaultPlan::at_fraction(frac, Side::Source)),
        )
        .unwrap();
    assert!(!out.completed, "{mech:?}/{method:?}@{frac}: fault did not fire");

    let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
    assert!(
        out2.completed,
        "{mech:?}/{method:?}@{frac}: resume failed: {:?}",
        out2.fault
    );
    // Resume must not start from scratch once anything was synced.
    if out.source.objects_synced > 0 {
        assert!(
            out2.source.objects_skipped_resume + out2.source.files_skipped_resume > 0,
            "{mech:?}/{method:?}@{frac}: nothing skipped despite {} synced",
            out.source.objects_synced
        );
    }
    env.verify_sink_complete()
        .unwrap_or_else(|e| panic!("{mech:?}/{method:?}@{frac}: {e}"));

    // After completion every log is gone.
    let left = recover::recover_all(&env.cfg.ft()).unwrap();
    assert!(
        left.is_empty(),
        "{mech:?}/{method:?}@{frac}: logs left after completion: {:?}",
        left.keys().collect::<Vec<_>>()
    );
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

macro_rules! matrix {
    ($($name:ident: $mech:expr, $method:expr;)+) => {
        $(
            #[test]
            fn $name() {
                for frac in [0.2, 0.4, 0.6, 0.8] {
                    run_matrix_case($mech, $method, frac, stringify!($name));
                }
            }
        )+
    };
}

matrix! {
    file_char: Mechanism::File, Method::Char;
    file_int: Mechanism::File, Method::Int;
    file_enc: Mechanism::File, Method::Enc;
    file_binary: Mechanism::File, Method::Binary;
    file_bit8: Mechanism::File, Method::Bit8;
    file_bit64: Mechanism::File, Method::Bit64;
    txn_char: Mechanism::Transaction, Method::Char;
    txn_int: Mechanism::Transaction, Method::Int;
    txn_enc: Mechanism::Transaction, Method::Enc;
    txn_binary: Mechanism::Transaction, Method::Binary;
    txn_bit8: Mechanism::Transaction, Method::Bit8;
    txn_bit64: Mechanism::Transaction, Method::Bit64;
    univ_char: Mechanism::Universal, Method::Char;
    univ_int: Mechanism::Universal, Method::Int;
    univ_enc: Mechanism::Universal, Method::Enc;
    univ_binary: Mechanism::Universal, Method::Binary;
    univ_bit8: Mechanism::Universal, Method::Bit8;
    univ_bit64: Mechanism::Universal, Method::Bit64;
}

#[test]
fn torture_profile_composes_with_matrix_kill_points() {
    // The matrix drill with the adversary armed on top: for every FT
    // mechanism, run the reorder profile under a mid-transfer kill and
    // resume (adversary still on). The composed `label_with` tag names
    // both legs in every assertion, and the invariants are exactly the
    // plain matrix ones — resume completes, logged objects are skipped,
    // sink byte-verifies, no logs survive.
    for mech in Mechanism::ALL_FT {
        let mut cfg = Config::for_tests(&format!("matrix-torture-{}", mech.as_str()));
        cfg.mechanism = mech;
        cfg.method = Method::Bit64;
        cfg.send_window = 4;
        cfg.ack_batch = 4;
        cfg.ack_flush_us = 500;
        cfg.torture_profile = "reorder".into();
        cfg.torture_seed = 0xA11CE;
        let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
        let env = SimEnv::new(cfg, &wl);
        let plan = FaultPlan::try_at_fraction(0.5, Side::Source)
            .expect("0.5 is a valid fault fraction");
        let label = plan.label_with(Some(&env.cfg.torture_profile));
        let out = env
            .run(&TransferSpec::fresh(env.files.clone()).with_fault(plan))
            .unwrap();
        assert!(!out.completed, "{mech:?} {label}: fault did not fire");
        let logged: u64 = recover::recover_all(&env.cfg.ft())
            .unwrap()
            .values()
            .map(|s| s.count() as u64)
            .sum();
        let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
        assert!(out2.completed, "{mech:?} {label}: resume failed: {:?}", out2.fault);
        assert!(
            out2.source.objects_skipped_resume >= logged,
            "{mech:?} {label}: logged objects not skipped"
        );
        env.verify_sink_complete()
            .unwrap_or_else(|e| panic!("{mech:?} {label}: {e}"));
        let left = recover::recover_all(&env.cfg.ft()).unwrap();
        assert!(left.is_empty(), "{mech:?} {label}: logs left after completion");
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn batched_acks_fault_mid_window_every_mechanism() {
    // The batched-ack pipeline: for every FT mechanism and several
    // ack_batch sizes, kill the connection mid-transfer (hence mid-flush-
    // window), resume, and require (a) completion + byte-verified sink,
    // (b) no acked-and-logged object is ever retransmitted — the resume
    // re-sends at most the un-acked tail (the in-flight flush windows),
    // which block re-write tolerates, and (c) no logs survive completion.
    for mech in Mechanism::ALL_FT {
        for batch in [2u32, 8, 64] {
            let mut cfg = Config::for_tests(&format!("matrix-ackb-{}-{batch}", mech.as_str()));
            cfg.mechanism = mech;
            cfg.method = Method::Bit64;
            cfg.ack_batch = batch;
            cfg.ack_flush_us = 500;
            let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
            let total = wl.total_objects(cfg.object_size);
            let env = SimEnv::new(cfg, &wl);
            let out = env
                .run(
                    &TransferSpec::fresh(env.files.clone())
                        .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
                )
                .unwrap();
            assert!(!out.completed, "{mech:?} batch={batch}: fault did not fire");
            // What the group-committed logs actually captured before the
            // fault: every one of those objects must be skipped, never
            // retransmitted, on resume.
            let logged: u64 = recover::recover_all(&env.cfg.ft())
                .unwrap()
                .values()
                .map(|s| s.count() as u64)
                .sum();
            let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
            assert!(
                out2.completed,
                "{mech:?} batch={batch}: resume failed: {:?}",
                out2.fault
            );
            assert!(
                out2.source.objects_skipped_resume >= logged,
                "{mech:?} batch={batch}: logged objects not skipped \
                 ({} skipped, {logged} logged)",
                out2.source.objects_skipped_resume
            );
            assert!(
                out2.source.objects_sent <= total - logged,
                "{mech:?} batch={batch}: resume retransmitted logged objects \
                 ({} sent, {logged} logged of {total})",
                out2.source.objects_sent
            );
            env.verify_sink_complete()
                .unwrap_or_else(|e| panic!("{mech:?} batch={batch}: {e}"));
            let left = recover::recover_all(&env.cfg.ft()).unwrap();
            assert!(
                left.is_empty(),
                "{mech:?} batch={batch}: logs left after completion"
            );
            let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        }
    }
}

#[test]
fn autotuned_transfer_fault_every_mechanism() {
    // The unified autotuner under faults: for every FT mechanism, run
    // with --tune walking the whole knob vector (window, ack batch,
    // both IO budgets, per-stream split) in real time and sever the
    // session mid-walk. The crash lands with floated knobs — a grown
    // credit window of un-acked NEW_BLOCKs, partially filled ack
    // batches — and resume (also tuned) must still honor the log-based
    // retransmit bound: every group-committed object is skipped, so at
    // most `total - logged` objects are re-sent, which block re-write
    // tolerates. Sink contents byte-verify and no logs survive.
    for mech in Mechanism::ALL_FT {
        let mut cfg = Config::for_tests(&format!("matrix-tune-{}", mech.as_str()));
        cfg.mechanism = mech;
        cfg.method = Method::Bit64;
        cfg.tune = true;
        cfg.tune_epoch_ms = 1;
        // for_tests' time_scale 0.0 finishes before one epoch ticks;
        // real time + wire latency lets the walk actually move.
        cfg.time_scale = 1.0;
        cfg.net_latency_us = 200;
        cfg.ack_flush_us = 500;
        cfg.data_streams = 2;
        let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
        let total = wl.total_objects(cfg.object_size);
        let env = SimEnv::new(cfg, &wl);
        let out = env
            .run(
                &TransferSpec::fresh(env.files.clone())
                    .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
            )
            .unwrap();
        assert!(!out.completed, "{mech:?} tuned: fault did not fire");
        let logged: u64 = recover::recover_all(&env.cfg.ft())
            .unwrap()
            .values()
            .map(|s| s.count() as u64)
            .sum();
        let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
        assert!(out2.completed, "{mech:?} tuned: resume failed: {:?}", out2.fault);
        assert!(
            out2.source.objects_skipped_resume >= logged,
            "{mech:?} tuned: logged objects not skipped ({} skipped, {logged} logged)",
            out2.source.objects_skipped_resume
        );
        assert!(
            out2.source.objects_sent <= total - logged,
            "{mech:?} tuned: resume retransmitted logged objects \
             ({} sent, {logged} logged of {total})",
            out2.source.objects_sent
        );
        env.verify_sink_complete()
            .unwrap_or_else(|e| panic!("{mech:?} tuned: {e}"));
        let left = recover::recover_all(&env.cfg.ft()).unwrap();
        assert!(left.is_empty(), "{mech:?} tuned: logs left after completion");
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn send_window_full_fault_every_mechanism() {
    // The windowed-issue pipeline: for every FT mechanism and
    // send_window ∈ {1, 4, 32}, sever the connection mid-transfer — with
    // a full credit window of un-acked NEW_BLOCKs in flight at the crash
    // — then resume and require the log-based retransmit bound: every
    // group-committed (logged) object is skipped, so the resume re-sends
    // at most `total - logged` objects (the un-acked window plus any
    // un-flushed ack batches), which block re-write tolerates. Sink
    // contents byte-verify and no logs survive completion.
    for mech in Mechanism::ALL_FT {
        for window in [1u32, 4, 32] {
            let mut cfg =
                Config::for_tests(&format!("matrix-swin-{}-{window}", mech.as_str()));
            cfg.mechanism = mech;
            cfg.method = Method::Bit64;
            cfg.send_window = window;
            cfg.ack_batch = 4;
            cfg.ack_flush_us = 500;
            let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
            let total = wl.total_objects(cfg.object_size);
            let env = SimEnv::new(cfg, &wl);
            let out = env
                .run(
                    &TransferSpec::fresh(env.files.clone())
                        .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
                )
                .unwrap();
            assert!(!out.completed, "{mech:?} window={window}: fault did not fire");
            assert_eq!(out.send_window, window, "negotiation must land the full window");
            let logged: u64 = recover::recover_all(&env.cfg.ft())
                .unwrap()
                .values()
                .map(|s| s.count() as u64)
                .sum();
            let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
            assert!(
                out2.completed,
                "{mech:?} window={window}: resume failed: {:?}",
                out2.fault
            );
            assert!(
                out2.source.objects_skipped_resume >= logged,
                "{mech:?} window={window}: logged objects not skipped \
                 ({} skipped, {logged} logged)",
                out2.source.objects_skipped_resume
            );
            assert!(
                out2.source.objects_sent <= total - logged,
                "{mech:?} window={window}: resume retransmitted logged objects \
                 ({} sent, {logged} logged of {total})",
                out2.source.objects_sent
            );
            env.verify_sink_complete()
                .unwrap_or_else(|e| panic!("{mech:?} window={window}: {e}"));
            let left = recover::recover_all(&env.cfg.ft()).unwrap();
            assert!(
                left.is_empty(),
                "{mech:?} window={window}: logs left after completion"
            );
            let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        }
    }
}

#[test]
fn coalesced_writes_fault_every_mechanism() {
    // The write-coalescing pipeline: for every FT mechanism, sever the
    // connection mid-transfer — with gathered runs potentially half-
    // written at the sink — then resume and require the log-based
    // retransmit bound (`resent <= total - logged`: every group-committed
    // object is skipped) and a final dataset byte-identical to what the
    // uncoalesced path produces. Coalescing must never change WHAT lands,
    // only how many write submissions carry it.
    for mech in Mechanism::ALL_FT {
        let mut cfg = Config::for_tests(&format!("matrix-coal-{}", mech.as_str()));
        cfg.mechanism = mech;
        cfg.method = Method::Bit64;
        cfg.write_coalesce_bytes = 4 << 20;
        cfg.send_window = 8;
        cfg.ack_batch = 4;
        cfg.ack_flush_us = 500;
        let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
        let total = wl.total_objects(cfg.object_size);
        let env = SimEnv::new(cfg, &wl);
        let out = env
            .run(
                &TransferSpec::fresh(env.files.clone())
                    .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
            )
            .unwrap();
        assert!(!out.completed, "{mech:?}: fault did not fire");
        let logged: u64 = recover::recover_all(&env.cfg.ft())
            .unwrap()
            .values()
            .map(|s| s.count() as u64)
            .sum();
        let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
        assert!(out2.completed, "{mech:?}: resume failed: {:?}", out2.fault);
        assert!(
            out2.source.objects_skipped_resume >= logged,
            "{mech:?}: logged objects not skipped ({} skipped, {logged} logged)",
            out2.source.objects_skipped_resume
        );
        assert!(
            out2.source.objects_sent <= total - logged,
            "{mech:?}: resume retransmitted logged objects \
             ({} sent, {logged} logged of {total})",
            out2.source.objects_sent
        );
        env.verify_sink_complete()
            .unwrap_or_else(|e| panic!("{mech:?}: {e}"));
        let left = recover::recover_all(&env.cfg.ft()).unwrap();
        assert!(left.is_empty(), "{mech:?}: logs left after completion");

        // Byte-identity vs coalesce-off: a reference transfer of the
        // same workload with coalescing disabled must leave the exact
        // same per-offset write digests at its sink.
        let mut ref_cfg = Config::for_tests(&format!("matrix-coal-ref-{}", mech.as_str()));
        ref_cfg.mechanism = mech;
        ref_cfg.method = Method::Bit64;
        assert_eq!(ref_cfg.write_coalesce_bytes, 0);
        let ref_env = SimEnv::new(ref_cfg, &wl);
        let ref_out = ref_env
            .run(&TransferSpec::fresh(ref_env.files.clone()))
            .unwrap();
        assert!(ref_out.completed, "{mech:?}: reference run failed");
        for name in &env.files {
            let size = env.source.lookup(name).unwrap().1.size;
            let blocks = ftlads::util::div_ceil(size, env.cfg.object_size);
            for b in 0..blocks {
                let offset = b * env.cfg.object_size;
                assert_eq!(
                    env.sink.written_digest(name, offset),
                    ref_env.sink.written_digest(name, offset),
                    "{mech:?}: '{name}' block {b} differs from the uncoalesced path"
                );
            }
        }
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        let _ = std::fs::remove_dir_all(&ref_env.cfg.ft_dir);
    }
}

#[test]
fn multi_stream_fault_every_mechanism() {
    // The multi-stream data plane under faults: for every FT mechanism
    // and data_streams ∈ {1, 2, 8}, sever the session mid-transfer (the
    // fault controller is shared by the control connection and every
    // data leg, so losing one leg kills them all — a TCP RST on any
    // socket of a striped session ends the session) with payload spread
    // across the per-stream credit windows at the crash. Resume must
    // honor the log-based retransmit bound (`resent <= total - logged`),
    // the sink must byte-verify, and no logs may survive completion —
    // identically at every stream count.
    for mech in Mechanism::ALL_FT {
        for streams in [1u32, 2, 8] {
            let mut cfg =
                Config::for_tests(&format!("matrix-mstream-{}-{streams}", mech.as_str()));
            cfg.mechanism = mech;
            cfg.method = Method::Bit64;
            cfg.data_streams = streams;
            cfg.send_window = 4;
            cfg.ack_batch = 4;
            cfg.ack_flush_us = 500;
            let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
            let total = wl.total_objects(cfg.object_size);
            let env = SimEnv::new(cfg, &wl);
            let out = env
                .run(
                    &TransferSpec::fresh(env.files.clone())
                        .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
                )
                .unwrap();
            assert!(!out.completed, "{mech:?} streams={streams}: fault did not fire");
            assert_eq!(
                out.data_streams, streams,
                "negotiation must land the configured stream count"
            );
            let logged: u64 = recover::recover_all(&env.cfg.ft())
                .unwrap()
                .values()
                .map(|s| s.count() as u64)
                .sum();
            let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
            assert!(
                out2.completed,
                "{mech:?} streams={streams}: resume failed: {:?}",
                out2.fault
            );
            assert!(
                out2.source.objects_skipped_resume >= logged,
                "{mech:?} streams={streams}: logged objects not skipped \
                 ({} skipped, {logged} logged)",
                out2.source.objects_skipped_resume
            );
            assert!(
                out2.source.objects_sent <= total - logged,
                "{mech:?} streams={streams}: resume retransmitted logged objects \
                 ({} sent, {logged} logged of {total})",
                out2.source.objects_sent
            );
            env.verify_sink_complete()
                .unwrap_or_else(|e| panic!("{mech:?} streams={streams}: {e}"));
            let left = recover::recover_all(&env.cfg.ft()).unwrap();
            assert!(
                left.is_empty(),
                "{mech:?} streams={streams}: logs left after completion"
            );
            let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        }
    }
}

#[test]
fn adaptive_acks_survive_mid_transfer_fault() {
    // ack_adaptive mid-flight: a crash while the effective batch floats
    // must lose at most the un-flushed acks, like the fixed-batch path.
    let mut cfg = Config::for_tests("matrix-adaptive-fault");
    cfg.mechanism = Mechanism::Universal;
    cfg.method = Method::Bit64;
    cfg.ack_batch = 8;
    cfg.ack_adaptive = true;
    cfg.ack_flush_us = 500;
    cfg.send_window = 8;
    let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
    let total = wl.total_objects(cfg.object_size);
    let env = SimEnv::new(cfg, &wl);
    let out = env
        .run(
            &TransferSpec::fresh(env.files.clone())
                .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
        )
        .unwrap();
    assert!(!out.completed, "fault did not fire");
    let logged: u64 = recover::recover_all(&env.cfg.ft())
        .unwrap()
        .values()
        .map(|s| s.count() as u64)
        .sum();
    let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
    assert!(out2.completed, "resume failed: {:?}", out2.fault);
    assert!(out2.source.objects_sent <= total - logged);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn batched_acks_with_corruption_retransmit_promptly() {
    // ok=false acks flush their batch immediately; corrupted writes are
    // retransmitted and the dataset still verifies with batching on.
    let mut cfg = Config::for_tests("matrix-ackb-corrupt");
    cfg.mechanism = Mechanism::Universal;
    cfg.method = Method::Bit64;
    cfg.ack_batch = 8;
    let wl = workload::big_workload(3, 4 * cfg.object_size);
    let env = SimEnv::new(cfg, &wl);
    for (f, b) in [(0usize, 0u64), (1, 1), (2, 3)] {
        env.sink
            .inject_write_corruption(&env.files[f], b * env.cfg.object_size);
    }
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.sink.objects_failed_verify, 3);
    assert_eq!(out.source.objects_failed_verify, 3);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn huge_ack_batch_relies_on_window_flush() {
    // ack_batch far above the per-file object count: the count trigger
    // never fires, so completion depends entirely on the flusher thread's
    // ack_flush_us straggler bound.
    let mut cfg = Config::for_tests("matrix-ackb-window");
    cfg.mechanism = Mechanism::File;
    cfg.method = Method::Bit64;
    cfg.ack_batch = 1024;
    cfg.ack_flush_us = 2000;
    let wl = workload::big_workload(3, 4 * cfg.object_size); // 12 objects
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    // Far fewer ack messages than objects: coalescing really happened.
    assert!(
        out.sink.ack_messages < out.source.objects_synced,
        "expected coalesced acks: {} msgs for {} objects",
        out.sink.ack_messages,
        out.source.objects_synced
    );
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn lads_without_ft_restarts_from_scratch() {
    let cfg = Config::for_tests("matrix-lads");
    // mechanism defaults to File; force None
    let mut cfg = cfg;
    cfg.mechanism = Mechanism::None;
    let wl = workload::big_workload(4, 8 * cfg.object_size);
    let env = SimEnv::new(cfg, &wl);
    let out = env
        .run(
            &TransferSpec::fresh(env.files.clone())
                .with_fault(FaultPlan::at_fraction(0.6, Side::Source)),
        )
        .unwrap();
    assert!(!out.completed);
    // "Resume" without logs: only whole committed files can be skipped;
    // everything else is retransmitted.
    let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
    assert!(out2.completed);
    assert_eq!(
        out2.source.objects_skipped_resume, 0,
        "no FT logs -> no object-level skips"
    );
    env.verify_sink_complete().unwrap();
}

#[test]
fn small_workload_file_equals_mtu_resume() {
    // Paper §6.4.2: with file == one MTU, resume reduces to whole-file
    // skip decisions; no partial logs should survive.
    for mech in Mechanism::ALL_FT {
        let mut cfg = Config::for_tests("matrix-small");
        cfg.mechanism = mech;
        cfg.method = Method::Bit8;
        let wl = workload::small_workload(24, cfg.object_size);
        let env = SimEnv::new(cfg, &wl);
        let out = env
            .run(
                &TransferSpec::fresh(env.files.clone())
                    .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
            )
            .unwrap();
        assert!(!out.completed);
        let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
        assert!(out2.completed, "{mech:?}: {:?}", out2.fault);
        env.verify_sink_complete().unwrap();
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn uneven_file_sizes_with_partial_tail_objects() {
    // Sizes that do NOT divide the MTU: tail objects are short.
    let mut cfg = Config::for_tests("matrix-uneven");
    cfg.mechanism = Mechanism::Universal;
    cfg.method = Method::Enc;
    let os = cfg.object_size;
    let wl = ftlads::workload::Workload {
        name: "uneven".into(),
        files: vec![
            ftlads::workload::FileSpec { name: "a".into(), size: 1 },
            ftlads::workload::FileSpec { name: "b".into(), size: os - 1 },
            ftlads::workload::FileSpec { name: "c".into(), size: os + 1 },
            ftlads::workload::FileSpec { name: "d".into(), size: 3 * os + 17 },
            ftlads::workload::FileSpec { name: "e".into(), size: 7 * os - 3 },
        ],
    };
    let env = SimEnv::new(cfg, &wl);
    let out = env
        .run(
            &TransferSpec::fresh(env.files.clone())
                .with_fault(FaultPlan::at_fraction(0.5, Side::Source)),
        )
        .unwrap();
    assert!(!out.completed);
    let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
    assert!(out2.completed, "{:?}", out2.fault);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn repeated_faults_eventually_complete() {
    // Fault -> resume(fault) -> resume(fault) -> resume: progress must be
    // monotone (seeded logs survive repeated crashes).
    let mut cfg = Config::for_tests("matrix-repeat");
    cfg.mechanism = Mechanism::File;
    cfg.method = Method::Bit64;
    let wl = workload::big_workload(6, 8 * cfg.object_size);
    let env = SimEnv::new(cfg, &wl);

    let mut spec = TransferSpec::fresh(env.files.clone())
        .with_fault(FaultPlan::at_fraction(0.3, Side::Source));
    let mut completed = false;
    for round in 0..6 {
        let out = env.run(&spec).unwrap();
        if out.completed {
            completed = true;
            break;
        }
        // Each subsequent round is a resume with a later fault point.
        let frac = 0.3 + 0.2 * (round as f64 + 1.0);
        spec = TransferSpec::resuming(env.files.clone());
        if frac < 1.0 {
            spec = spec.with_fault(FaultPlan::at_fraction(frac, Side::Source));
        }
    }
    assert!(completed, "did not complete after repeated faults");
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn sink_side_fault_attribution() {
    let mut cfg = Config::for_tests("matrix-sinkside");
    cfg.mechanism = Mechanism::Transaction;
    cfg.method = Method::Int;
    let wl = workload::big_workload(4, 4 * cfg.object_size);
    let env = SimEnv::new(cfg, &wl);
    let out = env
        .run(
            &TransferSpec::fresh(env.files.clone())
                .with_fault(FaultPlan::at_fraction(0.4, Side::Sink)),
        )
        .unwrap();
    assert!(!out.completed);
    assert!(out.fault.as_deref().unwrap_or("").contains("sink"));
    let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
    assert!(out2.completed);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn multiple_corruptions_all_retransmitted() {
    let mut cfg = Config::for_tests("matrix-corrupt");
    cfg.mechanism = Mechanism::Universal;
    cfg.method = Method::Bit64;
    let wl = workload::big_workload(3, 4 * cfg.object_size);
    let env = SimEnv::new(cfg, &wl);
    for (f, b) in [(0usize, 0u64), (1, 1), (2, 3)] {
        env.sink
            .inject_write_corruption(&env.files[f], b * env.cfg.object_size);
    }
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.sink.objects_failed_verify, 3);
    assert_eq!(out.source.objects_failed_verify, 3);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn integrity_off_misses_corruption_stock_lads_behaviour() {
    // §3.2: stock LADS acknowledges without verifying — the corrupted
    // object lands and nobody notices. Reproduce exactly that.
    let mut cfg = Config::for_tests("matrix-off");
    cfg.integrity = ftlads::integrity::IntegrityMode::Off;
    cfg.mechanism = Mechanism::None;
    let wl = workload::big_workload(2, 2 * cfg.object_size);
    let env = SimEnv::new(cfg, &wl);
    env.sink.inject_write_corruption(&env.files[0], 0);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed);
    assert_eq!(out.sink.objects_failed_verify, 0, "nothing detected");
    // The data really is corrupt at the sink.
    assert!(
        env.verify_sink_complete().is_err(),
        "corruption silently accepted must be visible to the ledger check"
    );
}
