//! Source-side NEW_BLOCK pipelining (credit-based `send_window`):
//! PR 2 equivalence at the defaults (byte-identical wire traces, same
//! logger write counts), CONNECT negotiation incl. legacy fallback, the
//! in-flight bound itself, the adaptive ack coalescer's feedback, the
//! send-window autotuner, and the zero-copy equivalence pins (every
//! payload-bearing frame on the wire byte-identical to a hand-rolled
//! reference encoding of the source file data).

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use ftlads::config::Config;
use ftlads::coordinator::sink::{SinkReport, SinkSession};
use ftlads::coordinator::source::{SourceReport, SourceSession};
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::net::{channel, Endpoint, FaultController, Message, NetError};
use ftlads::pfs::Pfs;
use ftlads::workload;

/// Endpoint wrapper recording the exact encoded bytes of every message
/// sent through it, plus the NEW_BLOCK in-flight high-water mark
/// (sends minus acknowledgements seen by the receive side).
struct ByteTap {
    inner: channel::ChannelEndpoint,
    sent: Arc<Mutex<Vec<Vec<u8>>>>,
    inflight: Arc<AtomicI64>,
    max_inflight: Arc<AtomicI64>,
}

impl ByteTap {
    fn new(inner: channel::ChannelEndpoint) -> (ByteTap, Arc<Mutex<Vec<Vec<u8>>>>, Arc<AtomicI64>) {
        let sent = Arc::new(Mutex::new(Vec::new()));
        let max_inflight = Arc::new(AtomicI64::new(0));
        let tap = ByteTap {
            inner,
            sent: sent.clone(),
            inflight: Arc::new(AtomicI64::new(0)),
            max_inflight: max_inflight.clone(),
        };
        (tap, sent, max_inflight)
    }

    fn track(&self, delta: i64) {
        let now = self.inflight.fetch_add(delta, Ordering::SeqCst) + delta;
        self.max_inflight.fetch_max(now, Ordering::SeqCst);
    }
}

impl Endpoint for ByteTap {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        let mut bytes = Vec::new();
        msg.encode(&mut bytes);
        self.sent.lock().unwrap_or_else(|e| e.into_inner()).push(bytes);
        if matches!(msg, Message::NewBlock { .. }) {
            self.track(1);
        }
        self.inner.send(msg)
    }

    fn recv(&self) -> Result<Message, NetError> {
        let msg = self.inner.recv()?;
        self.on_recv(&msg);
        Ok(msg)
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        let msg = self.inner.recv_timeout(timeout)?;
        self.on_recv(&msg);
        Ok(msg)
    }

    fn payload_sent(&self) -> u64 {
        self.inner.payload_sent()
    }
}

impl ByteTap {
    fn on_recv(&self, msg: &Message) {
        match msg {
            Message::BlockSync { .. } => self.track(-1),
            Message::BlockSyncBatch { blocks, .. } => self.track(-(blocks.len() as i64)),
            _ => {}
        }
    }
}

struct SplitRun {
    src: SourceReport,
    snk: SinkReport,
    /// Encoded bytes of every source-side send, in send order.
    src_sent: Vec<Vec<u8>>,
    /// Encoded bytes of every sink-side send, in send order.
    snk_sent: Vec<Vec<u8>>,
    /// High-water mark of un-acknowledged NEW_BLOCKs on the wire.
    max_inflight: i64,
}

/// Run one transfer with independent source/sink configs, byte-tapping
/// both endpoints.
fn run_split(src_cfg: &Config, sink_cfg: &Config, env: &SimEnv) -> SplitRun {
    let (src_ep, sink_ep) = channel::pair(src_cfg.wire(), FaultController::unarmed());
    let (src_tap, src_sent, max_inflight) = ByteTap::new(src_ep);
    let (snk_tap, snk_sent, _) = ByteTap::new(sink_ep);

    let sink_node = SinkSession::new(sink_cfg, env.sink.clone(), Arc::new(snk_tap))
        .spawn()
        .unwrap();
    let spec = TransferSpec::fresh(env.files.clone());
    let src = SourceSession::new(src_cfg, env.source.clone(), Arc::new(src_tap))
        .run(&spec)
        .unwrap();
    let snk = sink_node.join();
    SplitRun {
        src,
        snk,
        src_sent: src_sent.lock().unwrap_or_else(|e| e.into_inner()).clone(),
        snk_sent: snk_sent.lock().unwrap_or_else(|e| e.into_inner()).clone(),
        max_inflight: max_inflight.load(Ordering::SeqCst),
    }
}

/// Sorted copy — IO threads race, so cross-run comparison is by multiset.
fn sorted(trace: &[Vec<u8>]) -> Vec<Vec<u8>> {
    let mut t = trace.to_vec();
    t.sort();
    t
}

#[test]
fn defaults_produce_byte_identical_pr2_wire_trace() {
    // The acceptance pin: `send_window = 1` + `ack_adaptive = false`
    // (the defaults) must put exactly the PR 2 bytes on the wire — the
    // handshake carries no trailing send_window field, and the whole
    // trace is the same multiset of encoded messages as an explicitly
    // PR 2-configured run, with the same logger write count.
    let cfg = Config::for_tests("swin-pr2-eq");
    assert_eq!(cfg.send_window, 1, "default must be the lockstep path");
    assert!(!cfg.ack_adaptive, "default must be the fixed-batch path");
    let wl = workload::big_workload(4, 512 << 10); // 32 objects @ 64 KiB
    let env = SimEnv::new(cfg.clone(), &wl);
    let run_a = run_split(&cfg, &cfg, &env);
    assert!(run_a.src.fault.is_none(), "{:?}", run_a.src.fault);
    env.verify_sink_complete().unwrap();

    // The handshake bytes, hand-built to the PR 2 layout (no trailing
    // send_window field on either message).
    let mut connect = vec![0u8]; // T_CONNECT
    connect.extend_from_slice(&cfg.object_size.to_le_bytes());
    connect.extend_from_slice(&8u32.to_le_bytes()); // 8 RMA slots in tests
    connect.push(0); // resume = false
    connect.extend_from_slice(&1u32.to_le_bytes()); // ack_batch = 1
    assert_eq!(run_a.src_sent[0], connect, "CONNECT grew beyond the PR 2 bytes");
    let mut connect_ack = vec![1u8]; // T_CONNECT_ACK
    connect_ack.extend_from_slice(&8u32.to_le_bytes());
    connect_ack.extend_from_slice(&1u32.to_le_bytes()); // negotiated ack_batch
    assert_eq!(run_a.snk_sent[0], connect_ack, "CONNECT_ACK grew beyond the PR 2 bytes");

    // A second run with the knobs set explicitly is the same wire trace
    // (multiset — IO threads race on ordering) and the same write counts.
    let mut explicit = cfg.clone();
    explicit.send_window = 1;
    explicit.ack_adaptive = false;
    let env_b = SimEnv::new(explicit.clone(), &wl);
    let run_b = run_split(&explicit, &explicit, &env_b);
    assert!(run_b.src.fault.is_none(), "{:?}", run_b.src.fault);
    assert_eq!(sorted(&run_a.src_sent), sorted(&run_b.src_sent));
    assert_eq!(sorted(&run_a.snk_sent), sorted(&run_b.snk_sent));
    assert_eq!(run_a.src.counters.log_writes, 32, "one logger write per object");
    assert_eq!(run_a.src.counters.log_writes, run_b.src.counters.log_writes);
    assert_eq!(run_a.snk.counters.ack_messages, run_b.snk.counters.ack_messages);
    assert_eq!(run_a.src.send_window, 1);
    assert_eq!(run_a.snk.ack_batch_effective, 1);
    assert_eq!(run_a.src.counters.credit_waits, 0, "lockstep never takes credits");
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    let _ = std::fs::remove_dir_all(&env_b.cfg.ft_dir);
}

/// Hand-rolled reference encoding of a NEW_BLOCK frame — field-by-field,
/// independent of the codec under test. The zero-copy `Bytes` refactor
/// must not move a single wire byte.
fn reference_new_block(
    file_idx: u32,
    block_idx: u32,
    offset: u64,
    digest: u64,
    payload: &[u8],
) -> Vec<u8> {
    let mut buf = vec![4u8]; // T_NEW_BLOCK
    buf.extend_from_slice(&file_idx.to_le_bytes());
    buf.extend_from_slice(&block_idx.to_le_bytes());
    buf.extend_from_slice(&offset.to_le_bytes());
    buf.extend_from_slice(&digest.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

#[test]
fn payload_frames_match_reference_encoding_and_source_data() {
    // The zero-copy acceptance pin beyond the handshake: EVERY
    // payload-bearing frame the source puts on the wire — lockstep and
    // windowed — must equal a hand-built reference encoding whose
    // payload is read straight from the source PFS. A representation
    // change that leaked (offset slip, sliced-view confusion, header
    // drift) shows up as a byte mismatch here.
    for window in [1u32, 4] {
        let mut cfg = Config::for_tests(&format!("swin-payload-pin-{window}"));
        cfg.send_window = window;
        let wl = workload::mixed_workload(4, 192 << 10, cfg.seed);
        let env = SimEnv::new(cfg.clone(), &wl);
        let run = run_split(&cfg, &cfg, &env);
        assert!(run.src.fault.is_none(), "window={window}: {:?}", run.src.fault);
        env.verify_sink_complete().unwrap();

        let mut new_blocks = 0u64;
        for frame in &run.src_sent {
            if frame.first() != Some(&4u8) {
                continue; // not a NEW_BLOCK
            }
            new_blocks += 1;
            let Ok(Message::NewBlock { file_idx, block_idx, offset, digest, data }) =
                Message::decode(frame)
            else {
                panic!("NEW_BLOCK frame failed to decode");
            };
            // Re-read the object from the source PFS and rebuild the
            // frame by hand.
            let name = &env.files[file_idx as usize];
            let (fid, meta) = env.source.lookup(name).expect("source file present");
            let len = (meta.size - offset).min(cfg.object_size) as usize;
            let mut expect_payload = vec![0u8; len];
            assert_eq!(
                env.source.read_at(fid, offset, &mut expect_payload).unwrap(),
                len
            );
            assert_eq!(
                *frame,
                reference_new_block(file_idx, block_idx, offset, digest, &expect_payload),
                "window={window}: NEW_BLOCK frame for {name} block {block_idx} \
                 is not byte-identical to the reference encoding"
            );
            assert_eq!(data, expect_payload, "decoded payload must match the PFS data");
        }
        assert_eq!(
            new_blocks,
            run.src.counters.objects_sent,
            "every sent object must appear as a NEW_BLOCK frame in the trace"
        );
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn windowed_run_lands_identical_data_with_bounded_inflight() {
    // Pipelining changes only message timing: object/byte accounting and
    // sink contents must match the lockstep run, and the wire never
    // carries more than `send_window` un-acked NEW_BLOCKs.
    let mut outcomes = Vec::new();
    for window in [1u32, 4] {
        let mut cfg = Config::for_tests(&format!("swin-eq-{window}"));
        cfg.send_window = window;
        let wl = workload::mixed_workload(6, 256 << 10, cfg.seed);
        let env = SimEnv::new(cfg.clone(), &wl);
        let run = run_split(&cfg, &cfg, &env);
        assert!(run.src.fault.is_none(), "window={window}: {:?}", run.src.fault);
        assert!(run.snk.fault.is_none(), "window={window}: {:?}", run.snk.fault);
        env.verify_sink_complete().unwrap();
        assert_eq!(run.src.send_window, window);
        assert_eq!(run.snk.send_window, window);
        if window > 1 {
            assert!(
                run.max_inflight <= window as i64,
                "window={window}: {} un-acked NEW_BLOCKs in flight",
                run.max_inflight
            );
        }
        outcomes.push(run);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    let (lockstep, windowed) = (&outcomes[0], &outcomes[1]);
    assert_eq!(
        lockstep.src.counters.objects_sent,
        windowed.src.counters.objects_sent
    );
    assert_eq!(
        lockstep.src.counters.objects_synced,
        windowed.src.counters.objects_synced
    );
    assert_eq!(lockstep.src.counters.bytes_sent, windowed.src.counters.bytes_sent);
    assert_eq!(
        lockstep.src.counters.log_appends,
        windowed.src.counters.log_appends
    );
    assert_eq!(lockstep.src.files_done, windowed.src.files_done);
}

#[test]
fn connect_negotiation_takes_min_window_and_legacy_falls_back_to_lockstep() {
    for (src_win, sink_win, expect) in [(8u32, 2u32, 2u32), (2, 8, 2), (8, 1, 1), (1, 8, 1)] {
        let mut src_cfg = Config::for_tests(&format!("swin-neg-{src_win}-{sink_win}"));
        src_cfg.send_window = src_win;
        let mut sink_cfg = src_cfg.clone();
        sink_cfg.send_window = sink_win;
        let wl = workload::big_workload(2, 512 << 10); // 16 objects
        let env = SimEnv::new(src_cfg.clone(), &wl);
        let run = run_split(&src_cfg, &sink_cfg, &env);
        assert!(run.src.fault.is_none(), "{src_win}/{sink_win}: {:?}", run.src.fault);
        assert_eq!(
            run.src.send_window, expect,
            "source must honor min({src_win}, {sink_win})"
        );
        assert_eq!(run.snk.send_window, expect);
        if expect == 1 {
            assert_eq!(
                run.src.counters.credit_waits, 0,
                "negotiated lockstep must never touch the credit gate"
            );
        } else {
            assert!(run.max_inflight <= expect as i64);
        }
        env.verify_sink_complete().unwrap();
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn tiny_window_on_big_file_still_completes() {
    // send_window = 2 against a 32-object file: the credit gate cycles
    // dozens of times; everything must still arrive and verify.
    let mut cfg = Config::for_tests("swin-tiny");
    cfg.send_window = 2;
    cfg.io_threads = 4;
    let wl = workload::big_workload(1, 32 * cfg.object_size);
    let env = SimEnv::new(cfg.clone(), &wl);
    let run = run_split(&cfg, &cfg, &env);
    assert!(run.src.fault.is_none(), "{:?}", run.src.fault);
    assert_eq!(run.src.counters.objects_synced, 32);
    assert!(run.max_inflight <= 2);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn windowed_batched_acks_compose() {
    // Both knobs on at once: window 8 + ack_batch 8 over ONE 32-object
    // file, so the window and the coalescer are phase-locked — each full
    // window of NEW_BLOCKs produces exactly one count-driven
    // BLOCK_SYNC_BATCH, whose arrival refills all 8 credits at once.
    let mut cfg = Config::for_tests("swin-compose");
    cfg.send_window = 8;
    cfg.ack_batch = 8;
    cfg.ack_flush_us = 100_000; // count-driven flushes only
    let wl = workload::big_workload(1, 32 * cfg.object_size); // 32 objects
    let env = SimEnv::new(cfg.clone(), &wl);
    let run = run_split(&cfg, &cfg, &env);
    assert!(run.src.fault.is_none(), "{:?}", run.src.fault);
    assert_eq!(run.src.counters.objects_synced, 32);
    assert_eq!(run.snk.counters.ack_messages, 4, "one batch per credit window");
    assert_eq!(run.src.counters.log_writes, 4, "one group commit per batch");
    assert!(run.max_inflight <= 8);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn adaptive_ack_batch_grows_under_load_and_shrinks_on_partial_flushes() {
    // 13 objects against an adaptive cap of 8, one IO thread per side so
    // the ack sequence is strictly ordered: the effective batch must
    // grow off the floor (ack #1 is a trivially-filled one-ack batch,
    // then count-driven flushes double it: 1 + 2 + 4 = 7 acks) and the
    // un-divisible 6-object tail must be pushed out by the flush window,
    // shrinking it back — both movements observable in the counters and
    // the final effective value.
    let mut cfg = Config::for_tests("swin-adaptive");
    cfg.io_threads = 1;
    cfg.ack_batch = 8;
    cfg.ack_adaptive = true;
    cfg.ack_flush_us = 2_000;
    let wl = workload::big_workload(1, 13 * cfg.object_size); // 13 objects
    let env = SimEnv::new(cfg.clone(), &wl);
    let run = run_split(&cfg, &cfg, &env);
    assert!(run.src.fault.is_none(), "{:?}", run.src.fault);
    assert_eq!(run.src.counters.objects_synced, 13);
    assert!(
        run.snk.counters.ack_batch_grows >= 2,
        "count-driven flushes must grow the effective batch (got {})",
        run.snk.counters.ack_batch_grows
    );
    assert!(
        run.snk.counters.ack_batch_shrinks >= 1,
        "the partial tail must fire the window and shrink the batch"
    );
    assert!(
        (1..=8).contains(&run.snk.ack_batch_effective),
        "effective batch {} escaped [1, cap]",
        run.snk.ack_batch_effective
    );
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn out_of_range_ack_faults_cleanly_instead_of_panicking() {
    // A corrupt/malicious sink acks a block index far outside the file:
    // the source must treat it as a protocol violation (clean fault) —
    // the failed-write reschedule path would otherwise underflow the
    // `size - offset` length math on the wire-supplied index.
    let cfg = Config::for_tests("swin-rogue-ack");
    let wl = workload::big_workload(1, 4 * cfg.object_size); // 4 objects
    let env = SimEnv::new(cfg.clone(), &wl);
    let (src_ep, sink_ep) = channel::pair(cfg.wire(), FaultController::unarmed());

    // Scripted rogue sink: handshake + FILE_ID normally, then answer the
    // first NEW_BLOCK with an absurd index and keep draining until the
    // source hangs up.
    let rogue = std::thread::spawn(move || {
        let mut acked = false;
        loop {
            match sink_ep.recv_timeout(Duration::from_millis(100)) {
                Ok(Message::Connect { ack_batch, send_window, .. }) => {
                    let _ = sink_ep.send(Message::ConnectAck {
                        rma_slots: 8,
                        ack_batch,
                        send_window,
                        data_streams: 1,
                    });
                }
                Ok(Message::NewFile { file_idx, .. }) => {
                    let _ = sink_ep.send(Message::FileId {
                        file_idx,
                        sink_fd: 0,
                        skip: false,
                    });
                }
                Ok(Message::NewBlock { file_idx, .. }) if !acked => {
                    acked = true;
                    let _ = sink_ep.send(Message::BlockSync {
                        file_idx,
                        block_idx: u32::MAX,
                        ok: false,
                    });
                }
                Ok(_) => {}
                Err(NetError::Timeout) => continue,
                Err(_) => break, // source dropped its endpoint
            }
        }
    });

    let report = SourceSession::new(&cfg, env.source.clone(), Arc::new(src_ep))
        .run(&TransferSpec::fresh(env.files.clone()))
        .unwrap();
    let fault = report.fault.expect("rogue ack must fault the source");
    assert!(
        fault.contains("out-of-range block"),
        "expected a protocol-violation fault, got: {fault}"
    );
    rogue.join().unwrap();
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}

#[test]
fn adaptive_send_window_grows_from_credit_waits() {
    // The autotuner's grow leg: the applied window starts at the floor
    // of 1 while the sink coalesces acks 4-at-a-time behind a 2 ms flush
    // window — so the first un-acked object necessarily blocks the next
    // issue on a credit (the ack is parked in a partial batch), which
    // doubles the applied window toward the cap. The negotiated (wire)
    // window stays the cap.
    let mut cfg = Config::for_tests("swin-auto-grow");
    cfg.send_window = 8;
    cfg.send_window_adaptive = true;
    cfg.io_threads = 4;
    cfg.ack_batch = 4;
    cfg.ack_flush_us = 2_000;
    let wl = workload::big_workload(2, 16 * cfg.object_size); // 32 objects
    let env = SimEnv::new(cfg.clone(), &wl);
    let run = run_split(&cfg, &cfg, &env);
    assert!(run.src.fault.is_none(), "{:?}", run.src.fault);
    assert_eq!(run.src.counters.objects_synced, 32);
    assert_eq!(run.src.send_window, 8, "negotiation must still land the cap");
    assert!(
        run.src.counters.credit_waits >= 1,
        "four threads against an applied window of 1 must contend"
    );
    assert!(
        run.src.counters.send_window_grows >= 1,
        "a credit wait must grow the applied window"
    );
    assert!(
        (1..=8).contains(&run.src.send_window_effective),
        "applied window {} escaped [1, cap]",
        run.src.send_window_effective
    );
    assert!(run.max_inflight <= 8, "the cap still bounds the wire");
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn adaptive_send_window_shrinks_on_slot_stalls() {
    // The shrink leg: a 2-slot RMA pool under a wire-bound link keeps
    // the pool dry (zero-copy pins each buffer across the serialization
    // and the sink's write), so issue-loop slot stalls must fire and
    // each one halves the applied window — observable in the shrink
    // counter. Grow events race against them; the invariant is that both
    // legs actually actuate and the window stays in range.
    let mut cfg = Config::for_tests("swin-auto-shrink");
    cfg.send_window = 8;
    cfg.send_window_adaptive = true;
    cfg.io_threads = 4;
    cfg.rma_bytes = 2 * cfg.object_size as usize;
    cfg.time_scale = 1.0;
    cfg.net_bandwidth = 2.0e8; // ~330 µs per 64 KiB object on the wire
    cfg.net_latency_us = 5;
    cfg.ost_bandwidth = f64::INFINITY;
    cfg.ost_latency_us = 0;
    cfg.ost_concurrent = 8;
    let wl = workload::big_workload(3, 16 * cfg.object_size); // 48 objects
    let env = SimEnv::new(cfg.clone(), &wl);
    let run = run_split(&cfg, &cfg, &env);
    assert!(run.src.fault.is_none(), "{:?}", run.src.fault);
    assert_eq!(run.src.counters.objects_synced, 48);
    assert!(
        run.src.counters.send_stalls >= 1,
        "a 2-slot pool on a wire-bound link must stall the issue loop"
    );
    assert!(
        run.src.counters.send_window_grows >= 1,
        "the floor-of-1 start must grow under 4 threads"
    );
    assert!(
        run.src.counters.send_window_shrinks >= 1,
        "slot stalls must shrink the applied window"
    );
    assert!((1..=8).contains(&run.src.send_window_effective));
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn adaptive_send_window_against_lockstep_peer_is_inert() {
    // Negotiated down to a window of 1, the autotuner has nothing to
    // float: the gate is disabled, no credits are taken, no feedback
    // fires, and the applied window reports 1.
    let mut src_cfg = Config::for_tests("swin-auto-lockstep");
    src_cfg.send_window = 8;
    src_cfg.send_window_adaptive = true;
    let mut sink_cfg = src_cfg.clone();
    sink_cfg.send_window = 1;
    sink_cfg.send_window_adaptive = false;
    let wl = workload::big_workload(2, 512 << 10); // 16 objects
    let env = SimEnv::new(src_cfg.clone(), &wl);
    let run = run_split(&src_cfg, &sink_cfg, &env);
    assert!(run.src.fault.is_none(), "{:?}", run.src.fault);
    assert_eq!(run.src.send_window, 1, "negotiation must fall back to lockstep");
    assert_eq!(run.src.send_window_effective, 1);
    assert_eq!(run.src.counters.credit_waits, 0);
    assert_eq!(run.src.counters.send_window_grows, 0);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn adaptive_against_legacy_peer_stays_per_object() {
    // An adaptive sink negotiated down to ack_batch = 1 must behave
    // exactly like the seed: singles only, no growth possible.
    let mut src_cfg = Config::for_tests("swin-adaptive-legacy");
    src_cfg.ack_batch = 1;
    let mut sink_cfg = src_cfg.clone();
    sink_cfg.ack_batch = 8;
    sink_cfg.ack_adaptive = true;
    let wl = workload::big_workload(2, 512 << 10); // 16 objects
    let env = SimEnv::new(src_cfg.clone(), &wl);
    let run = run_split(&src_cfg, &sink_cfg, &env);
    assert!(run.src.fault.is_none(), "{:?}", run.src.fault);
    assert_eq!(run.snk.counters.ack_messages, 16, "per-object acks only");
    assert_eq!(run.snk.ack_batch_effective, 1);
    assert_eq!(run.snk.counters.ack_batch_grows, 0);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}
