//! Property-based tests for the FT logging subsystem (testutil::forall
//! drives deterministic PCG-seeded cases; see DESIGN.md §8 for why this
//! replaces proptest offline).
//!
//! Core invariant — **log/recover round-trip**: for any mechanism, any
//! method, any file set, any out-of-order completion order (with
//! duplicates), and any crash point, `recover_all` returns exactly the
//! set of completions logged before the crash for non-completed files,
//! and nothing for completed files.

use std::collections::BTreeMap;

use ftlads::ftlog::{
    self, codec::Method, recover, CompletedSet, FtConfig, Mechanism,
};
use ftlads::testutil::{forall, Pcg32};
use ftlads::{prop_assert, prop_assert_eq};

fn tmp_dir(tag: &str, case: u64) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "ftlads-prop-{tag}-{case}-{}",
        std::process::id()
    ))
}

fn random_mechanism(rng: &mut Pcg32) -> Mechanism {
    *rng.choose(&Mechanism::ALL_FT)
}

fn random_method(rng: &mut Pcg32) -> Method {
    *rng.choose(&Method::ALL)
}

#[test]
fn prop_log_recover_roundtrip() {
    let mut case_id = 0u64;
    forall("log_recover_roundtrip", 60, |rng| {
        case_id += 1;
        let dir = tmp_dir("rt", case_id);
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = FtConfig {
            mechanism: random_mechanism(rng),
            method: random_method(rng),
            dir: dir.clone(),
            txn_size: rng.range(1, 6) as usize,
        };
        let mut logger = ftlog::create_logger(&cfg).map_err(|e| e.to_string())?;

        let nfiles = rng.range(1, 8) as usize;
        let mut expected: BTreeMap<String, CompletedSet> = BTreeMap::new();
        let mut keys = Vec::new();
        let mut totals = Vec::new();
        for f in 0..nfiles {
            let total = rng.range(1, 200) as u32;
            let name = format!("d/f{f}");
            let key = logger
                .register_file(&name, total)
                .map_err(|e| e.to_string())?;
            keys.push((name.clone(), key));
            totals.push(total);
            expected.insert(name, CompletedSet::new(total));
        }

        // Random interleaved completions with duplicates.
        let ops = rng.range(0, 400);
        for _ in 0..ops {
            let fi = rng.below(nfiles as u32) as usize;
            let (name, key) = &keys[fi];
            let block = rng.below(totals[fi]);
            logger.log_block(*key, block).map_err(|e| e.to_string())?;
            expected.get_mut(name).unwrap().insert(block);
        }

        // Randomly complete some files whose sets we then expect absent.
        for fi in 0..nfiles {
            if rng.bool(0.3) {
                let (name, key) = &keys[fi];
                logger.complete_file(*key).map_err(|e| e.to_string())?;
                expected.remove(name);
            }
        }
        drop(logger); // crash point: whatever is on disk is what recovery sees

        let recovered = recover::recover_all(&cfg).map_err(|e| e.to_string())?;
        // Files with zero logged blocks may legitimately have no log file
        // (light-weight logging) — drop empty sets from expectation.
        let expected: BTreeMap<_, _> = expected
            .into_iter()
            .filter(|(_, s)| s.count() > 0)
            .collect();
        let recovered: BTreeMap<_, _> = recovered
            .into_iter()
            .filter(|(_, s)| s.count() > 0)
            .collect();
        prop_assert_eq!(recovered, expected);
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

#[test]
fn prop_completed_set_semantics_match_btreeset() {
    forall("completed_set_model", 200, |rng| {
        let total = rng.range(1, 500) as u32;
        let mut set = CompletedSet::new(total);
        let mut model = std::collections::BTreeSet::new();
        for _ in 0..rng.range(0, 600) {
            let b = rng.below(total);
            prop_assert_eq!(set.insert(b), model.insert(b));
        }
        prop_assert_eq!(set.count() as usize, model.len());
        prop_assert_eq!(
            set.iter_completed().collect::<Vec<_>>(),
            model.iter().copied().collect::<Vec<_>>()
        );
        let pending = set.pending();
        prop_assert_eq!(pending.len() + model.len(), total as usize);
        for b in pending {
            prop_assert!(!model.contains(&b));
        }
        prop_assert_eq!(set.is_complete(), model.len() == total as usize);
        // u32-word bitmap popcount agrees.
        let pop: u32 = set.to_u32_words().iter().map(|w| w.count_ones()).sum();
        prop_assert_eq!(pop, set.count());
        Ok(())
    });
}

#[test]
fn prop_record_codecs_roundtrip() {
    forall("record_codec", 200, |rng| {
        let method = *rng.choose(&[Method::Char, Method::Int, Method::Enc, Method::Binary]);
        let n = rng.range(0, 200) as usize;
        let blocks: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let mut buf = Vec::new();
        for &b in &blocks {
            method.encode_record(b, &mut buf);
        }
        prop_assert_eq!(method.decode_stream(&buf), blocks);
        Ok(())
    });
}

#[test]
fn prop_torn_tail_loses_at_most_last_record() {
    forall("torn_tail", 150, |rng| {
        let method = *rng.choose(&[Method::Int, Method::Enc, Method::Binary]);
        let n = rng.range(2, 50) as usize;
        let blocks: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let mut buf = Vec::new();
        for &b in &blocks {
            method.encode_record(b, &mut buf);
        }
        // Tear 1..record_len-1 bytes off the end.
        let cut = rng.range(1, 3) as usize;
        if buf.len() <= cut {
            return Ok(());
        }
        buf.truncate(buf.len() - cut);
        let got = method.decode_stream(&buf);
        // All but the last record must survive intact.
        prop_assert!(got.len() >= n - 1, "lost more than the torn record");
        prop_assert_eq!(got[..n - 1].to_vec(), blocks[..n - 1].to_vec());
        Ok(())
    });
}

#[test]
fn prop_vld_varint_roundtrip_and_ordering() {
    forall("vld", 300, |rng| {
        let v = rng.next_u32();
        let mut buf = Vec::new();
        let n = ftlog::vld::encode_u32(v, &mut buf);
        prop_assert_eq!(n, ftlog::vld::encoded_len(v));
        let (back, used) = ftlog::vld::decode_u32(&buf).ok_or("decode failed")?;
        prop_assert_eq!(back, v);
        prop_assert_eq!(used, n);
        // Monotone length: longer values never encode shorter.
        let w = rng.next_u32();
        let (small, large) = if v <= w { (v, w) } else { (w, v) };
        prop_assert!(ftlog::vld::encoded_len(small) <= ftlog::vld::encoded_len(large));
        Ok(())
    });
}

#[test]
fn prop_bitmap_region_equals_set_bits() {
    // For bitmap methods, the bytes in the log region must equal the
    // in-memory set exactly (Algorithm 1 word updates must not clobber
    // neighbours).
    let mut case_id = 0u64;
    forall("bitmap_region", 60, |rng| {
        case_id += 1;
        let method = *rng.choose(&[Method::Bit8, Method::Bit64]);
        let dir = tmp_dir("bm", case_id);
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = FtConfig {
            mechanism: Mechanism::File,
            method,
            dir: dir.clone(),
            txn_size: 4,
        };
        let total = rng.range(1, 300) as u32;
        let mut logger = ftlog::create_logger(&cfg).map_err(|e| e.to_string())?;
        let key = logger.register_file("f", total).map_err(|e| e.to_string())?;
        let mut model = CompletedSet::new(total);
        for _ in 0..rng.range(1, 400) {
            let b = rng.below(total);
            logger.log_block(key, b).map_err(|e| e.to_string())?;
            model.insert(b);
        }
        drop(logger);
        let rec = recover::recover_all(&cfg).map_err(|e| e.to_string())?;
        prop_assert_eq!(rec.get("f").cloned().ok_or("missing f")?, model);
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}

#[test]
fn prop_escape_name_injective_roundtrip() {
    forall("escape", 300, |rng| {
        // Random byte-ish strings incl. separators and UTF-8.
        let pool = [
            "a", "B", "9", ".", "_", "-", "/", " ", "%", "\n", "α", "試", "%2f", "..",
        ];
        let n = rng.range(0, 12) as usize;
        let name: String = (0..n).map(|_| *rng.choose(&pool)).collect();
        let esc = ftlog::escape_name(&name);
        prop_assert!(esc.bytes().all(|b| {
            b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-' || b == b'%'
        }));
        prop_assert_eq!(ftlog::unescape_name(&esc).ok_or("unescape failed")?, name);
        Ok(())
    });
}

#[test]
fn prop_region_logger_space_bounded_by_live_files() {
    // Universal logger with serial complete: space must stay O(one file),
    // not O(dataset) — the region-reuse invariant behind Fig 7.
    let mut case_id = 0u64;
    forall("region_space", 20, |rng| {
        case_id += 1;
        let dir = tmp_dir("space", case_id);
        let _ = std::fs::remove_dir_all(&dir);
        let method = random_method(rng);
        let cfg = FtConfig {
            mechanism: Mechanism::Universal,
            method,
            dir: dir.clone(),
            txn_size: 4,
        };
        let total = rng.range(8, 64) as u32;
        let mut logger = ftlog::create_logger(&cfg).map_err(|e| e.to_string())?;
        let files = rng.range(10, 30) as usize;
        for f in 0..files {
            let key = logger
                .register_file(&format!("f{f}"), total)
                .map_err(|e| e.to_string())?;
            for b in 0..total {
                logger.log_block(key, b).map_err(|e| e.to_string())?;
            }
            logger.complete_file(key).map_err(|e| e.to_string())?;
        }
        let region = method.region_bytes(total) as u64;
        let space = logger.space();
        // Log bytes (excluding the append-only index) bounded by ~2 regions.
        let log_bytes = ftlog::dir_bytes(&dir).saturating_sub(
            std::fs::metadata(dir.join("index.tidx"))
                .map(|m| m.len())
                .unwrap_or(0),
        );
        prop_assert!(
            log_bytes <= 2 * region,
            "log grew to {log_bytes} for region {region} over {files} serial files"
        );
        prop_assert!(space.peak_bytes > 0);
        let _ = std::fs::remove_dir_all(&dir);
        Ok(())
    });
}
