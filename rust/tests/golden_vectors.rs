//! Cross-language golden vectors: `integrity::native` must reproduce
//! tests/golden/digest_vectors.json (generated from python ref.py), the
//! same file python/tests/test_golden.py asserts. This pins the
//! rust-native / jnp-ref / Pallas-kernel / PJRT-artifact quadrangle to a
//! committed ground truth.

use ftlads::integrity::native::{digest_words, popcount_words};
use ftlads::util::json::Json;

fn load() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/digest_vectors.json");
    let text = std::fs::read_to_string(path).expect("golden vectors present");
    Json::parse(&text).expect("golden vectors parse")
}

fn words_of(case: &Json) -> Vec<u32> {
    case.get("words")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap() as u32)
        .collect()
}

#[test]
fn native_digest_matches_golden() {
    let data = load();
    let cases = data.get("digest").as_arr().unwrap();
    assert!(cases.len() >= 8, "golden file incomplete");
    for (i, case) in cases.iter().enumerate() {
        let words = words_of(case);
        let d = digest_words(&words);
        assert_eq!(d.a as u64, case.get("a").as_u64().unwrap(), "case {i}: A");
        assert_eq!(d.b as u64, case.get("b").as_u64().unwrap(), "case {i}: B");
    }
}

#[test]
fn native_popcount_matches_golden() {
    let data = load();
    for (i, case) in data.get("popcount").as_arr().unwrap().iter().enumerate() {
        let words = words_of(case);
        assert_eq!(
            popcount_words(&words) as u64,
            case.get("popcount").as_u64().unwrap(),
            "case {i}"
        );
    }
}

#[test]
fn pjrt_artifact_matches_golden() {
    // Skipped when artifacts are absent.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let service = ftlads::runtime::RuntimeService::start(&dir).unwrap();
    let handle = service.handle();
    let w = handle.manifest.object_words;
    let b = handle.manifest.digest_batch;
    let data = load();
    for (i, case) in data.get("digest").as_arr().unwrap().iter().enumerate() {
        let words = words_of(case);
        if words.len() > w {
            continue;
        }
        // Zero-padding to the artifact width W changes the position
        // weights, so recompute the expected digest natively at width W —
        // the *native* path is already pinned to the golden file above;
        // here we pin PJRT == native at the artifact shape.
        let mut padded = words.clone();
        padded.resize(w, 0);
        let expect = digest_words(&padded);
        let mut batch = vec![0u32; b * w];
        batch[..w].copy_from_slice(&padded);
        let out = handle.execute_u32("digest", vec![batch]).unwrap();
        assert_eq!(out[0][0], expect.a, "case {i}: A via PJRT");
        assert_eq!(out[0][1], expect.b, "case {i}: B via PJRT");
    }
}
