//! Sink-side contiguous-write coalescing: seed equivalence at
//! `write_coalesce_bytes = 0`, the gathered-run win itself (fewer write
//! submissions, one OST service round per run), per-block ack/verify
//! semantics inside runs, the failed-vectored-write degradation path,
//! and the CONNECT-time RMA pool autosizer.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;
use ftlads::config::Config;
use ftlads::coordinator::sink::SinkSession;
use ftlads::coordinator::source::SourceSession;
use ftlads::coordinator::{SimEnv, TransferJob, TransferSpec};
use ftlads::net::{channel, Endpoint, FaultController, Message, NetError};
use ftlads::pfs::ost::OstConfig;
use ftlads::pfs::sim::SimPfs;
use ftlads::pfs::{FileId, FileMeta, Pfs, StripeLayout};
use ftlads::workload;

/// Endpoint wrapper recording the type of every message sent through it
/// (sink side: observes the ack wire shapes).
struct Tap {
    inner: channel::ChannelEndpoint,
    sent_types: Arc<Mutex<Vec<&'static str>>>,
}

impl Endpoint for Tap {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        self.sent_types
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(msg.type_name());
        self.inner.send(msg)
    }

    fn recv(&self) -> Result<Message, NetError> {
        self.inner.recv()
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        self.inner.recv_timeout(timeout)
    }

    fn payload_sent(&self) -> u64 {
        self.inner.payload_sent()
    }
}

fn count(types: &[&'static str], name: &str) -> usize {
    types.iter().filter(|t| **t == name).count()
}

/// A SimEnv whose *sink* storage is slow and strictly serial per OST
/// while the source/wire are instant — write queues genuinely build up,
/// so contiguous runs form deterministically instead of racing the
/// drain. `blocks_per_file` objects per file land on one OST each
/// (stripe_count 1, file < one stripe).
fn slow_sink_env(files: usize, blocks_per_file: u64, mut cfg: Config) -> SimEnv {
    cfg.send_window = 64;
    cfg.rma_bytes = 64 * cfg.object_size as usize;
    let wl = workload::big_workload(files, blocks_per_file * cfg.object_size);
    let source = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
    source.populate(&wl.as_tuples());
    let slow = OstConfig {
        bandwidth: 1e12,
        base_latency: Duration::from_millis(1),
        max_concurrent: 1,
        time_scale: 1.0,
    };
    let sink = Arc::new(SimPfs::new(cfg.layout(), slow, cfg.seed));
    let files = wl.files.iter().map(|f| f.name.clone()).collect();
    SimEnv { cfg, source, sink, files }
}

#[test]
fn coalesce_off_is_ack_for_ack_identical_to_seed() {
    // The acceptance pin: at write_coalesce_bytes = 0 (the default) the
    // sink write path is the PR 4 path exactly — one pwrite and one
    // single BLOCK_SYNC per object, no gathered runs, no batch messages,
    // and the configured RMA pool untouched.
    let cfg = Config::for_tests("coal-seed-eq");
    assert_eq!(cfg.write_coalesce_bytes, 0, "default must be the seed path");
    assert!(!cfg.rma_autosize, "autosizing must be opt-in");
    let wl = workload::big_workload(4, 512 << 10); // 32 objects @ 64 KiB
    let env = SimEnv::new(cfg.clone(), &wl);

    let (src_ep, sink_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
    let sent_types = Arc::new(Mutex::new(Vec::new()));
    let tap = Tap { inner: sink_ep, sent_types: sent_types.clone() };
    let sink_node = SinkSession::new(&cfg, env.sink.clone(), Arc::new(tap))
        .spawn()
        .unwrap();
    let spec = TransferSpec::fresh(env.files.clone());
    let src = SourceSession::new(&cfg, env.source.clone(), Arc::new(src_ep))
        .run(&spec)
        .unwrap();
    let snk = sink_node.join();
    let types = sent_types.lock().unwrap_or_else(|e| e.into_inner()).clone();

    assert!(src.fault.is_none(), "{:?}", src.fault);
    assert!(snk.fault.is_none(), "{:?}", snk.fault);
    assert_eq!(count(&types, "BLOCK_SYNC"), 32, "one ack per object");
    assert_eq!(count(&types, "BLOCK_SYNC_BATCH"), 0);
    assert_eq!(snk.counters.ack_messages, 32);
    assert_eq!(snk.counters.write_syscalls, 32, "one pwrite per object");
    assert_eq!(snk.counters.coalesced_runs, 0);
    assert_eq!(snk.counters.coalesce_bytes_max, 0);
    // One scheduler service round per object, exactly as before.
    assert_eq!(snk.sched.completes, 32);
    assert_eq!(src.counters.log_writes, 32, "one logger write per ack");
    assert_eq!(snk.rma_bytes_effective, cfg.rma_bytes as u64);
    assert_eq!(src.rma_bytes_effective, cfg.rma_bytes as u64);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn coalescing_gathers_runs_but_keeps_per_block_acks_and_logs() {
    // With a 4 MiB gather budget on a contiguous workload, the sink
    // submits measurably fewer writes — but every block is still
    // individually acked, logged, and content-verified.
    let mut cfg = Config::for_tests("coal-gather");
    cfg.write_coalesce_bytes = 4 << 20;
    let env = slow_sink_env(4, 8, cfg); // 32 objects
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);

    let objects = out.source.objects_sent;
    assert_eq!(objects, 32);
    assert!(
        out.sink.coalesced_runs > 0,
        "contiguous backlog must form gathered runs"
    );
    assert!(
        out.sink.write_syscalls * 2 <= objects,
        "coalescing must at least halve write submissions: {} syscalls for {objects} objects",
        out.sink.write_syscalls
    );
    assert!(out.sink.coalesce_bytes_max > env.cfg.object_size);
    assert!(out.sink.coalesce_bytes_max <= 4 << 20);
    // Per-block semantics unchanged: one ack and one log append per
    // object (ack_batch = 1), nothing failed.
    assert_eq!(out.sink.ack_messages, objects);
    assert_eq!(out.source.log_appends, objects);
    assert_eq!(out.source.objects_synced, objects);
    assert_eq!(out.sink.objects_failed_verify, 0);
    // The OST model saw one service round per gathered run, not per
    // object — the congestion-avoidance win the OST model exposes.
    let ost_writes = env.sink.ost_model().total_stats().writes;
    assert_eq!(ost_writes, out.sink.write_syscalls);
    // Scheduler feedback stays per-object (run samples split evenly), so
    // stateful policies see comparable numbers with coalescing on.
    assert_eq!(out.sink_sched.completes, objects);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn corruption_mid_run_fails_only_that_block() {
    // A corrupted persist inside a gathered run must fail exactly that
    // block's verify (per-block digest semantics), get retransmitted,
    // and leave the final dataset byte-identical.
    let mut cfg = Config::for_tests("coal-corrupt");
    cfg.write_coalesce_bytes = 4 << 20;
    let env = slow_sink_env(3, 8, cfg);
    // Corrupt a mid-file block of file 1 (offset 3 * object_size).
    env.sink
        .inject_write_corruption(&env.files[1], 3 * env.cfg.object_size);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.sink.objects_failed_verify, 1);
    assert_eq!(out.source.objects_failed_verify, 1);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

/// A PFS whose vectored write always fails: the sink must degrade to
/// per-block writes with unchanged fault semantics.
struct NoGatherPfs {
    inner: Arc<SimPfs>,
}

impl Pfs for NoGatherPfs {
    fn layout(&self) -> &StripeLayout {
        self.inner.layout()
    }
    fn ost_model(&self) -> &ftlads::pfs::OstModel {
        self.inner.ost_model()
    }
    fn lookup(&self, name: &str) -> Option<(FileId, FileMeta)> {
        self.inner.lookup(name)
    }
    fn list(&self) -> Vec<String> {
        self.inner.list()
    }
    fn create(&self, name: &str, size: u64, start_ost: u32) -> Result<FileId> {
        self.inner.create(name, size, start_ost)
    }
    fn read_at(&self, file: FileId, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.inner.read_at(file, offset, buf)
    }
    fn write_at(&self, file: FileId, offset: u64, data: &[u8]) -> Result<bool> {
        self.inner.write_at(file, offset, data)
    }
    fn write_at_vectored(
        &self,
        _file: FileId,
        _offset: u64,
        _iovs: &[&[u8]],
    ) -> Result<Vec<usize>> {
        anyhow::bail!("gather I/O unavailable")
    }
    fn commit_file(&self, file: FileId) -> Result<()> {
        self.inner.commit_file(file)
    }
    fn remove(&self, name: &str) -> Result<()> {
        self.inner.remove(name)
    }
}

#[test]
fn failed_vectored_write_degrades_to_per_block_and_completes() {
    let mut cfg = Config::for_tests("coal-degrade");
    cfg.write_coalesce_bytes = 4 << 20;
    let env = slow_sink_env(3, 8, cfg); // 24 objects
    let gateless: Arc<dyn Pfs> = Arc::new(NoGatherPfs { inner: env.sink.clone() });
    let out = TransferJob::builder(&env.cfg, &TransferSpec::fresh(env.files.clone()))
        .source_pfs(env.source.clone())
        .sink_pfs(gateless)
        .run()
        .unwrap();
    assert!(out.completed, "{:?}", out.fault);
    // Every gathered submission failed over to per-block writes: the
    // syscall count collapses back to one per object and no run is
    // counted as coalesced.
    assert_eq!(out.sink.write_syscalls, 24);
    assert_eq!(out.sink.coalesced_runs, 0);
    assert_eq!(out.sink.objects_failed_verify, 0);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

/// A PFS that blocks the FIRST write until the test releases it, so a
/// follow-up block can deterministically arrive while its predecessor's
/// write is in flight.
struct GatePfs {
    inner: Arc<SimPfs>,
    armed: std::sync::atomic::AtomicBool,
    started: std::sync::mpsc::Sender<()>,
    release: Mutex<std::sync::mpsc::Receiver<()>>,
}

impl GatePfs {
    fn gate(&self) {
        if self.armed.swap(false, std::sync::atomic::Ordering::SeqCst) {
            let _ = self.started.send(());
            let _ = self
                .release
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .recv_timeout(Duration::from_secs(10));
        }
    }
}

impl Pfs for GatePfs {
    fn layout(&self) -> &StripeLayout {
        self.inner.layout()
    }
    fn ost_model(&self) -> &ftlads::pfs::OstModel {
        self.inner.ost_model()
    }
    fn lookup(&self, name: &str) -> Option<(FileId, FileMeta)> {
        self.inner.lookup(name)
    }
    fn list(&self) -> Vec<String> {
        self.inner.list()
    }
    fn create(&self, name: &str, size: u64, start_ost: u32) -> Result<FileId> {
        self.inner.create(name, size, start_ost)
    }
    fn read_at(&self, file: FileId, offset: u64, buf: &mut [u8]) -> Result<usize> {
        self.inner.read_at(file, offset, buf)
    }
    fn write_at(&self, file: FileId, offset: u64, data: &[u8]) -> Result<bool> {
        self.gate();
        self.inner.write_at(file, offset, data)
    }
    fn write_at_vectored(&self, file: FileId, offset: u64, iovs: &[&[u8]]) -> Result<Vec<usize>> {
        self.gate();
        self.inner.write_at_vectored(file, offset, iovs)
    }
    fn commit_file(&self, file: FileId) -> Result<()> {
        self.inner.commit_file(file)
    }
    fn remove(&self, name: &str) -> Result<()> {
        self.inner.remove(name)
    }
}

#[test]
fn coalescer_continues_run_after_successor_arrives_mid_write() {
    // The PR 5 interaction fix: a gathered run that ran out of queued
    // successors must NOT give up on the chain. After the write (and its
    // per-block acks, which may flush on the ack-batch timer in between)
    // the IO thread re-drains the queue for the byte-successor of the
    // run it just wrote and continues, instead of falling back to the
    // scheduler for an unrelated pick. Scripted source + a write gate
    // make the interleaving deterministic: block 1 arrives while block
    // 0's write is parked inside the PFS.
    let mut cfg = Config::for_tests("coal-continue");
    cfg.write_coalesce_bytes = 4 << 20;
    cfg.ack_batch = 4; // acks park in the coalescer across the boundary
    cfg.io_threads = 1;
    cfg.integrity = ftlads::integrity::IntegrityMode::Off;
    let wl = workload::big_workload(1, 2 * cfg.object_size); // 2 blocks
    let env = SimEnv::new(cfg.clone(), &wl);
    let name = env.files[0].clone();
    let (fid, meta) = env.source.lookup(&name).unwrap();
    // The exact synthetic payloads the sink's ledger expects.
    let osz = cfg.object_size as usize;
    let mut b0 = vec![0u8; osz];
    let mut b1 = vec![0u8; osz];
    env.source.read_at(fid, 0, &mut b0).unwrap();
    env.source.read_at(fid, cfg.object_size, &mut b1).unwrap();

    let (started_tx, started_rx) = std::sync::mpsc::channel();
    let (release_tx, release_rx) = std::sync::mpsc::channel();
    let gate = Arc::new(GatePfs {
        inner: env.sink.clone(),
        armed: std::sync::atomic::AtomicBool::new(true),
        started: started_tx,
        release: Mutex::new(release_rx),
    });
    let (src_ep, sink_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
    let node = SinkSession::new(&cfg, gate, Arc::new(sink_ep)).spawn().unwrap();

    // Scripted source: handshake, open the file, then the gated dance.
    src_ep
        .send(Message::Connect {
            max_object_size: cfg.object_size,
            rma_slots: 8,
            resume: false,
            ack_batch: 4,
            send_window: 1,
            data_streams: 1,
            job: 0,
        })
        .unwrap();
    let Message::ConnectAck { .. } = src_ep.recv_timeout(Duration::from_secs(5)).unwrap()
    else {
        panic!("expected CONNECT_ACK")
    };
    src_ep
        .send(Message::NewFile {
            file_idx: 0,
            name: name.clone(),
            size: meta.size,
            start_ost: meta.start_ost,
        })
        .unwrap();
    let Message::FileId { skip: false, .. } =
        src_ep.recv_timeout(Duration::from_secs(5)).unwrap()
    else {
        panic!("expected FILE_ID without skip")
    };
    let send_block = |idx: u32, offset: u64, data: &[u8]| {
        src_ep
            .send(Message::NewBlock {
                file_idx: 0,
                block_idx: idx,
                offset,
                digest: 0, // integrity off
                data: ftlads::util::bytes::Bytes::from_vec(data.to_vec()),
            })
            .unwrap();
    };
    send_block(0, 0, &b0);
    // Block 0's write is now parked inside the PFS gate; block 1 lands
    // in the write queue while the run is mid-flight.
    started_rx.recv_timeout(Duration::from_secs(10)).unwrap();
    send_block(1, cfg.object_size, &b1);
    std::thread::sleep(Duration::from_millis(200)); // let the sink queue it
    release_tx.send(()).unwrap();

    // Both blocks must come back acked ok (singly or batched — the
    // ack-batch timer decides, and the continuation must not care).
    let mut acked = 0;
    while acked < 2 {
        match src_ep.recv_timeout(Duration::from_secs(5)).unwrap() {
            Message::BlockSync { ok, .. } => {
                assert!(ok);
                acked += 1;
            }
            Message::BlockSyncBatch { blocks, .. } => {
                assert!(blocks.iter().all(|(_, ok)| *ok));
                acked += blocks.len();
            }
            other => panic!("unexpected {}", other.type_name()),
        }
    }
    src_ep.send(Message::FileClose { file_idx: 0 }).unwrap();
    let Message::FileCloseAck { .. } = src_ep.recv_timeout(Duration::from_secs(5)).unwrap()
    else {
        panic!("expected FILE_CLOSE_ACK")
    };
    src_ep.send(Message::Bye).unwrap();
    let snk = node.join();
    assert!(snk.fault.is_none(), "{:?}", snk.fault);
    assert_eq!(
        snk.counters.coalesce_continuations, 1,
        "the drained chain must continue into the block that arrived mid-write"
    );
    assert_eq!(snk.counters.write_syscalls, 2, "one write per single-block run");
    assert_eq!(snk.counters.bytes_written, 2 * cfg.object_size);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn rma_autosize_grows_both_pools_to_the_negotiated_window() {
    // A 2-slot pool with a 16-deep window: without the autosizer the
    // transfer limps along on pool back-pressure; with it both sides
    // register window × object_size at CONNECT and report it.
    for autosize in [false, true] {
        let mut cfg = Config::for_tests(&format!("coal-autosize-{autosize}"));
        cfg.send_window = 16;
        cfg.rma_bytes = 2 * cfg.object_size as usize;
        cfg.rma_autosize = autosize;
        let wl = workload::big_workload(3, 8 * cfg.object_size); // 24 objects
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed, "autosize={autosize}: {:?}", out.fault);
        let want = if autosize {
            16 * env.cfg.object_size
        } else {
            env.cfg.rma_bytes as u64
        };
        assert_eq!(out.rma_bytes_effective, want, "autosize={autosize}");
        env.verify_sink_complete().unwrap();
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn rma_autosize_respects_the_negotiated_minimum() {
    // Source asks for window 16 but the sink caps it at 4: the autosizer
    // must size to the NEGOTIATED window (4 slots), not the request.
    let mut src_cfg = Config::for_tests("coal-autosize-min");
    src_cfg.send_window = 16;
    src_cfg.rma_bytes = 2 * src_cfg.object_size as usize;
    src_cfg.rma_autosize = true;
    let mut sink_cfg = src_cfg.clone();
    sink_cfg.send_window = 4;
    let wl = workload::big_workload(2, 8 * src_cfg.object_size);
    let env = SimEnv::new(src_cfg.clone(), &wl);

    let (src_ep, sink_ep) = channel::pair(src_cfg.wire(), FaultController::unarmed());
    let sink_node = SinkSession::new(&sink_cfg, env.sink.clone(), Arc::new(sink_ep))
        .spawn()
        .unwrap();
    let spec = TransferSpec::fresh(env.files.clone());
    let src = SourceSession::new(&src_cfg, env.source.clone(), Arc::new(src_ep))
        .run(&spec)
        .unwrap();
    let snk = sink_node.join();
    assert!(src.fault.is_none(), "{:?}", src.fault);
    assert_eq!(src.send_window, 4, "negotiation lands the min");
    assert_eq!(src.rma_bytes_effective, 4 * src_cfg.object_size);
    assert_eq!(snk.rma_bytes_effective, 4 * sink_cfg.object_size);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}
