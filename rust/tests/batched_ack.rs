//! Batched BLOCK_SYNC acknowledgements: seed equivalence at
//! `ack_batch = 1`, wire-level message shapes, CONNECT negotiation with
//! mixed-config (and legacy) peers, and the coalescing win itself.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use ftlads::config::Config;
use ftlads::coordinator::sink::{SinkReport, SinkSession};
use ftlads::coordinator::source::{SourceReport, SourceSession};
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::net::{channel, Endpoint, FaultController, Message, NetError};
use ftlads::workload;

/// Endpoint wrapper that records the type of every message sent through
/// it (used on the sink side to observe the ack wire shapes).
struct Tap {
    inner: channel::ChannelEndpoint,
    sent_types: Arc<Mutex<Vec<&'static str>>>,
}

impl Endpoint for Tap {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        self.sent_types
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(msg.type_name());
        self.inner.send(msg)
    }

    fn recv(&self) -> Result<Message, NetError> {
        self.inner.recv()
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        self.inner.recv_timeout(timeout)
    }

    fn payload_sent(&self) -> u64 {
        self.inner.payload_sent()
    }
}

/// Run one transfer with *independent* source and sink configs (the
/// in-process `run_transfer` shares one config, so negotiation tests
/// wire the nodes together manually), tapping the sink's send side.
fn run_split(
    src_cfg: &Config,
    sink_cfg: &Config,
    env: &SimEnv,
) -> (SourceReport, SinkReport, Vec<&'static str>) {
    let (src_ep, sink_ep) = channel::pair(src_cfg.wire(), FaultController::unarmed());
    let sent_types = Arc::new(Mutex::new(Vec::new()));
    let tap = Tap { inner: sink_ep, sent_types: sent_types.clone() };

    let sink_node = SinkSession::new(sink_cfg, env.sink.clone(), Arc::new(tap))
        .spawn()
        .unwrap();
    let spec = TransferSpec::fresh(env.files.clone());
    let src_report = SourceSession::new(src_cfg, env.source.clone(), Arc::new(src_ep))
        .run(&spec)
        .unwrap();
    let sink_report = sink_node.join();
    let types = sent_types.lock().unwrap_or_else(|e| e.into_inner()).clone();
    (src_report, sink_report, types)
}

fn count(types: &[&'static str], name: &str) -> usize {
    types.iter().filter(|t| **t == name).count()
}

#[test]
fn ack_batch_1_reproduces_seed_single_block_sync_exactly() {
    // The acceptance pin: at ack_batch = 1 the wire carries one single
    // BLOCK_SYNC per object — never a BLOCK_SYNC_BATCH — and the seed's
    // counter profile is reproduced exactly (one logger write per ack).
    let cfg = Config::for_tests("ackb-seed-eq");
    assert_eq!(cfg.ack_batch, 1, "default must be the seed path");
    let wl = workload::big_workload(4, 512 << 10); // 32 objects @ 64 KiB
    let env = SimEnv::new(cfg.clone(), &wl);
    let (src, snk, types) = run_split(&cfg, &cfg, &env);

    assert!(src.fault.is_none(), "{:?}", src.fault);
    assert!(snk.fault.is_none(), "{:?}", snk.fault);
    assert_eq!(count(&types, "BLOCK_SYNC"), 32);
    assert_eq!(count(&types, "BLOCK_SYNC_BATCH"), 0);
    assert_eq!(src.counters.objects_synced, 32);
    assert_eq!(snk.counters.ack_messages, 32);
    assert_eq!(src.counters.log_appends, 32);
    assert_eq!(src.counters.log_writes, 32, "one logger write per ack");
    assert_eq!(src.files_done, 4);
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn negotiated_batching_coalesces_wire_acks_and_log_writes() {
    let mut cfg = Config::for_tests("ackb-coalesce");
    cfg.ack_batch = 8;
    cfg.ack_flush_us = 100_000; // count-driven flushes only
    let wl = workload::big_workload(4, 512 << 10); // 4 files x 8 objects
    let env = SimEnv::new(cfg.clone(), &wl);
    let (src, snk, types) = run_split(&cfg, &cfg, &env);

    assert!(src.fault.is_none(), "{:?}", src.fault);
    assert_eq!(src.counters.objects_synced, 32);
    // 8 objects per file, batch 8: exactly one batch message per file.
    assert_eq!(count(&types, "BLOCK_SYNC"), 0, "batch>1 never sends singles");
    assert_eq!(count(&types, "BLOCK_SYNC_BATCH"), 4);
    assert_eq!(snk.counters.ack_messages, 4);
    assert_eq!(src.counters.log_appends, 32, "every object still logged");
    assert_eq!(src.counters.log_writes, 4, "one group commit per batch");
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn connect_negotiation_takes_the_min_of_both_sides() {
    // A batching sink facing a legacy-style source (ack_batch = 1) must
    // fall back to singles; a batching source facing an ack_batch = 1
    // sink gets singles too.
    for (src_batch, sink_batch) in [(1u32, 8u32), (8, 1)] {
        let mut src_cfg = Config::for_tests(&format!("ackb-neg-{src_batch}-{sink_batch}"));
        src_cfg.ack_batch = src_batch;
        let mut sink_cfg = src_cfg.clone();
        sink_cfg.ack_batch = sink_batch;
        let wl = workload::big_workload(2, 512 << 10); // 16 objects
        let env = SimEnv::new(src_cfg.clone(), &wl);
        let (src, snk, types) = run_split(&src_cfg, &sink_cfg, &env);
        assert!(src.fault.is_none(), "{:?}", src.fault);
        assert_eq!(
            count(&types, "BLOCK_SYNC"),
            16,
            "min(ack_batch)=1 must produce per-object acks ({src_batch}/{sink_batch})"
        );
        assert_eq!(count(&types, "BLOCK_SYNC_BATCH"), 0);
        assert_eq!(snk.counters.ack_messages, 16);
        env.verify_sink_complete().unwrap();
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
}

#[test]
fn batched_outcome_matches_per_object_outcome() {
    // Same workload, same seed: batch = 8 must land byte-identical data
    // and identical object accounting to batch = 1 — only the wire-
    // message and logger-write counts differ.
    let mut outcomes = Vec::new();
    for batch in [1u32, 8] {
        let mut cfg = Config::for_tests(&format!("ackb-outcome-{batch}"));
        cfg.ack_batch = batch;
        cfg.ack_flush_us = 100_000; // count-driven flushes only
        let wl = workload::mixed_workload(6, 256 << 10, cfg.seed);
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed, "batch={batch}: {:?}", out.fault);
        env.verify_sink_complete().unwrap();
        outcomes.push(out);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }
    let (single, batched) = (&outcomes[0], &outcomes[1]);
    assert_eq!(single.source.objects_sent, batched.source.objects_sent);
    assert_eq!(single.source.objects_synced, batched.source.objects_synced);
    assert_eq!(single.source.bytes_sent, batched.source.bytes_sent);
    assert_eq!(single.source.files_completed, batched.source.files_completed);
    assert_eq!(single.source.log_appends, batched.source.log_appends);
    assert!(
        batched.sink.ack_messages < single.sink.ack_messages,
        "batching must reduce wire acks: {} vs {}",
        batched.sink.ack_messages,
        single.sink.ack_messages
    );
    assert!(
        batched.source.log_writes < single.source.log_writes,
        "batching must reduce logger writes: {} vs {}",
        batched.source.log_writes,
        single.source.log_writes
    );
}

#[test]
fn sched_counters_populated_in_outcome() {
    // The per-policy pick/latency counters ride along in TransferOutcome.
    let cfg = Config::for_tests("ackb-schedctr");
    let wl = workload::big_workload(4, 512 << 10); // 32 objects
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    // Every object is picked once per side (no retransmits here).
    assert_eq!(out.source_sched.picks, 32);
    assert_eq!(out.sink_sched.picks, 32);
    assert_eq!(out.source_sched.completes, 32);
    assert_eq!(out.sink_sched.completes, 32);
    assert_eq!(out.source_sched.fallback_picks, 0);
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}
