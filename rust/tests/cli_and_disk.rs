//! Launcher-level integration: drive the actual `ftlads` binary —
//! single-process simulated transfers via the CLI, and the two-process
//! TCP deployment (sink process + source process over loopback with
//! DiskPfs roots), verifying real bytes on a real file system.

use std::path::PathBuf;
use std::process::Command;

fn ftlads() -> Command {
    Command::new(env!("CARGO_BIN_EXE_ftlads"))
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ftlads-cli-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn cli_transfer_completes_and_verifies() {
    let ftdir = tmp("t1");
    let out = ftlads()
        .args([
            "transfer",
            "--workload", "big",
            "--files", "4",
            "--file-size", "512K",
            "--mechanism", "universal",
            "--method", "bit64",
            "--ft-dir", ftdir.to_str().unwrap(),
            "--set", "time_scale=0",
        ])
        .output()
        .expect("spawn ftlads");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "stdout: {stdout}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("completed        : true"), "{stdout}");
    assert!(stdout.contains("sink dataset verified"), "{stdout}");
    let _ = std::fs::remove_dir_all(&ftdir);
}

#[test]
fn cli_ack_batch_flag_coalesces_and_reports() {
    let ftdir = tmp("t1b");
    let out = ftlads()
        .args([
            "transfer",
            "--workload", "big",
            "--files", "4",
            "--file-size", "512K",
            "--mechanism", "universal",
            "--method", "bit64",
            "--ack-batch", "8",
            "--ack-flush-us", "100000",
            "--ft-dir", ftdir.to_str().unwrap(),
            "--set", "time_scale=0",
        ])
        .output()
        .expect("spawn ftlads");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "stdout: {stdout}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("completed        : true"), "{stdout}");
    // 512K files / 256K default MTU = 2 objects per file: with batch 8
    // the window flush coalesces each file's acks into one message.
    assert!(stdout.contains("ack path         : 4 wire acks  4 logger writes"), "{stdout}");
    assert!(stdout.contains("sched (source)"), "{stdout}");
    let _ = std::fs::remove_dir_all(&ftdir);
}

#[test]
fn cli_adaptive_send_window_and_zero_copy_summary() {
    // --send-window-adaptive flows through the launcher, the summary
    // reports both RMA stall sides, and the counter-instrumented
    // zero-copy line shows exactly one payload copy per object
    // (8 files x 2 objects = 16 copies, one pread each).
    let ftdir = tmp("t1c");
    let out = ftlads()
        .args([
            "transfer",
            "--workload", "big",
            "--files", "8",
            "--file-size", "512K",
            "--mechanism", "universal",
            "--method", "bit64",
            "--send-window", "8",
            "--send-window-adaptive",
            "--ft-dir", ftdir.to_str().unwrap(),
            "--set", "time_scale=0",
        ])
        .output()
        .expect("spawn ftlads");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "stdout: {stdout}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("completed        : true"), "{stdout}");
    assert!(stdout.contains("send path        : window 8 (eff "), "{stdout}");
    assert!(stdout.contains("zero-copy        : 16 payload copies"), "{stdout}");
    assert!(stdout.contains("rma stalls       : src "), "{stdout}");
    let _ = std::fs::remove_dir_all(&ftdir);
}

#[test]
fn cli_write_coalesce_and_rma_autosize_summary() {
    // --write-coalesce-bytes / --rma-autosize flow through the launcher;
    // the summary's write-path line reports the syscall/run counters and
    // the autosized pool (window 16 x 256 KiB MTU = 4 MiB).
    let ftdir = tmp("t1d");
    let out = ftlads()
        .args([
            "transfer",
            "--workload", "big",
            "--files", "4",
            "--file-size", "512K",
            "--mechanism", "universal",
            "--method", "bit64",
            "--send-window", "16",
            "--write-coalesce-bytes", "4M",
            "--rma-autosize",
            "--set", "rma_bytes=512K",
            "--ft-dir", ftdir.to_str().unwrap(),
            "--set", "time_scale=0",
        ])
        .output()
        .expect("spawn ftlads");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "stdout: {stdout}\nstderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("completed        : true"), "{stdout}");
    // 4 files x 2 objects: 8 writes uncoalesced, fewer if runs formed —
    // either way the line is present and the autosized pool is 4 MiB.
    assert!(stdout.contains("write path       : "), "{stdout}");
    assert!(stdout.contains("rma pool 4.0 MiB"), "{stdout}");
    let _ = std::fs::remove_dir_all(&ftdir);
}

#[test]
fn cli_fault_exits_2_then_recover_shows_state() {
    let ftdir = tmp("t2");
    let common = [
        "--workload", "big",
        "--files", "6",
        "--file-size", "512K",
        "--mechanism", "file",
        "--method", "bit8",
        "--set", "time_scale=0",
    ];
    let out = ftlads()
        .args(["transfer"])
        .args(common)
        .args(["--ft-dir", ftdir.to_str().unwrap(), "--fault", "0.5"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2), "fault run must exit 2");

    // recover subcommand sees the in-flight state.
    let out = ftlads()
        .args([
            "recover",
            "--mechanism", "file",
            "--method", "bit8",
            "--ft-dir", ftdir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("in-flight file(s)"), "{stdout}");
    assert!(stdout.contains("pending"), "{stdout}");
    let _ = std::fs::remove_dir_all(&ftdir);
}

#[test]
fn cli_json_output_parses() {
    let ftdir = tmp("t3");
    let out = ftlads()
        .args([
            "transfer",
            "--workload", "small",
            "--files", "8",
            "--json",
            "--ft-dir", ftdir.to_str().unwrap(),
            "--set", "time_scale=0",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let json_line = stdout
        .lines()
        .find(|l| l.starts_with('{'))
        .expect("json line present");
    let v = ftlads::util::json::Json::parse(json_line).expect("valid json");
    assert_eq!(v.get("completed"), &ftlads::util::json::Json::Bool(true));
    assert!(v.get("objects_synced").as_u64().unwrap() >= 8);
    let _ = std::fs::remove_dir_all(&ftdir);
}

#[test]
fn cli_doctor_reports_pjrt() {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping doctor test: artifacts not built");
        return;
    }
    let out = ftlads()
        .args(["doctor", "--artifacts", artifacts.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("PJRT client      : ok"), "{stdout}");
    assert!(stdout.contains("execute          : ok"), "{stdout}");
}

#[test]
fn two_process_tcp_transfer_with_disk_pfs() {
    // Real sockets, real files, two OS processes.
    let root = tmp("twoproc");
    let src_root = root.join("src");
    let sink_root = root.join("sink");
    std::fs::create_dir_all(&src_root).unwrap();

    // Stage a small real dataset (deterministic contents).
    let staging = root.join("staging");
    std::fs::create_dir_all(&staging).unwrap();
    let mut rng = ftlads::testutil::Pcg32::new(99);
    for i in 0..5 {
        let mut data = vec![0u8; 200_000 + i * 17];
        rng.fill_bytes(&mut data);
        std::fs::write(staging.join(format!("f{i}.bin")), data).unwrap();
    }
    {
        use ftlads::pfs::{disk::DiskPfs, StripeLayout};
        let pfs = DiskPfs::new(
            &src_root,
            StripeLayout::paper(),
            ftlads::pfs::ost::OstConfig { time_scale: 0.0, ..Default::default() },
        )
        .unwrap();
        assert_eq!(pfs.import_dir(&staging).unwrap(), 5);
    }

    // Pick a free port by binding and releasing.
    let port = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");

    let mut sink = ftlads()
        .args([
            "sink",
            "--listen", &addr,
            "--root", sink_root.to_str().unwrap(),
            "--set", "time_scale=0",
        ])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawn sink");
    std::thread::sleep(std::time::Duration::from_millis(400));

    let ftdir = root.join("ftlog");
    let out = ftlads()
        .args([
            "source",
            "--connect", &addr,
            "--root", src_root.to_str().unwrap(),
            "--mechanism", "universal",
            "--method", "bit64",
            "--ft-dir", ftdir.to_str().unwrap(),
            "--set", "time_scale=0",
        ])
        .output()
        .expect("run source");
    let src_out = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "source failed: {src_out}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(src_out.contains("transfer complete"), "{src_out}");

    let status = sink.wait().expect("sink exit");
    assert!(status.success(), "sink failed");

    // Byte-for-byte comparison.
    for i in 0..5 {
        let name = format!("f{i}.bin");
        let a = std::fs::read(staging.join(&name)).unwrap();
        let b = std::fs::read(sink_root.join(&name)).unwrap();
        assert_eq!(a, b, "content mismatch in {name}");
    }
    let _ = std::fs::remove_dir_all(&root);
}
