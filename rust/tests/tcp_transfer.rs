//! Transfer over the TCP transport: the two-process deployment path
//! (source and sink nodes joined by real loopback sockets with full
//! message serialization), exercised in-process — plus the raw-socket
//! frame pin for the zero-copy vectored send path.

use std::io::Read;
use std::sync::Arc;

use ftlads::config::Config;
use ftlads::coordinator::{self, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{Mechanism, Method};
use ftlads::net::{tcp, Endpoint, FaultController, Message, Side, WireModel};
use ftlads::pfs::sim::SimPfs;
use ftlads::pfs::Pfs;
use ftlads::util::bytes::Bytes;
use ftlads::workload;

struct TcpEnv {
    cfg: Config,
    source: Arc<SimPfs>,
    sink: Arc<SimPfs>,
    files: Vec<String>,
}

impl TcpEnv {
    fn new(tag: &str, nfiles: usize, size: u64) -> TcpEnv {
        let mut cfg = Config::for_tests(tag);
        cfg.mechanism = Mechanism::Universal;
        cfg.method = Method::Bit64;
        let wl = workload::big_workload(nfiles, size);
        let source = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
        source.populate(&wl.as_tuples());
        let sink = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
        let files = wl.files.iter().map(|f| f.name.clone()).collect();
        TcpEnv { cfg, source, sink, files }
    }

    fn run(&self, fault: FaultPlan, resume: bool) -> coordinator::TransferOutcome {
        let total: u64 = self
            .files
            .iter()
            .map(|n| self.source.lookup(n).unwrap().1.size)
            .sum();
        let ctl = fault.arm(total);
        let (src_ep, sink_ep) =
            tcp::loopback_pair(WireModel::none(), ctl).expect("tcp pair");
        let src_ep: Arc<dyn Endpoint> = Arc::new(src_ep);
        let sink_ep: Arc<dyn Endpoint> = Arc::new(sink_ep);

        let sink_node =
            coordinator::sink::SinkSession::new(&self.cfg, self.sink.clone() as Arc<dyn Pfs>, sink_ep)
                .spawn()
                .expect("spawn sink");
        let spec = TransferSpec { files: self.files.clone(), resume, fault: FaultPlan::none() };
        let src_report = coordinator::source::SourceSession::new(
            &self.cfg,
            self.source.clone() as Arc<dyn Pfs>,
            src_ep.clone(),
        )
        .run(&spec)
        .expect("run source");
        let sink_report = sink_node.join();
        let fault_msg = src_report.fault.clone().or(sink_report.fault);
        coordinator::TransferOutcome {
            completed: fault_msg.is_none()
                && src_report.files_done as usize == self.files.len(),
            fault: fault_msg,
            elapsed: std::time::Duration::ZERO,
            source: src_report.counters,
            sink: sink_report.counters,
            log_space: src_report.log_space,
            resources: Default::default(),
            payload_bytes: src_ep.payload_sent(),
            rma_stalls_src: src_report.rma_stalls,
            rma_stalls_snk: sink_report.rma_stalls,
            source_sched: src_report.sched,
            sink_sched: sink_report.sched,
            send_window: src_report.send_window,
            send_window_effective: src_report.send_window_effective,
            ack_batch_effective: sink_report.ack_batch_effective,
            rma_bytes_effective: src_report.rma_bytes_effective,
            data_streams: src_report.data_streams,
            tune_epochs: 0,
            tune_grows: 0,
            tune_shrinks: 0,
            tune_reverts: 0,
            goodput_final: 0.0,
            tune_trajectory: Vec::new(),
        }
    }

    fn verify(&self) {
        for name in &self.files {
            let (_, meta) = self.sink.lookup(name).expect("file at sink");
            assert!(meta.committed, "{name} not committed");
            let objects =
                (meta.size + self.cfg.object_size - 1) / self.cfg.object_size;
            for b in 0..objects {
                let offset = b * self.cfg.object_size;
                let len = (meta.size - offset).min(self.cfg.object_size) as usize;
                let (got, _) = self
                    .sink
                    .written_digest(name, offset)
                    .unwrap_or_else(|| panic!("{name} block {b} missing"));
                assert_eq!(got, self.source.expected_digest(name, offset, len));
            }
        }
    }
}

#[test]
fn tcp_full_transfer() {
    let env = TcpEnv::new("tcp1", 5, 512 << 10);
    let out = env.run(FaultPlan::none(), false);
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.source.objects_synced, 5 * 8);
    env.verify();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn tcp_fault_then_resume() {
    let env = TcpEnv::new("tcp2", 6, 512 << 10);
    let out = env.run(FaultPlan::at_fraction(0.5, Side::Source), false);
    assert!(!out.completed, "fault should trigger over TCP too");
    let out2 = env.run(FaultPlan::none(), true);
    assert!(out2.completed, "{:?}", out2.fault);
    assert!(
        out2.source.objects_skipped_resume + out2.source.files_skipped_resume > 0,
        "resume should reuse progress"
    );
    env.verify();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn tcp_batched_acks_roundtrip_the_codec() {
    // BLOCK_SYNC_BATCH serialized through the real wire codec over
    // loopback sockets: coalescing survives the byte-level path, and a
    // mid-transfer fault still resumes to a verified dataset.
    let mut env = TcpEnv::new("tcp4", 5, 512 << 10);
    env.cfg.ack_batch = 8;
    env.cfg.ack_flush_us = 100_000;
    let out = env.run(FaultPlan::none(), false);
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.source.objects_synced, 5 * 8);
    // 8 objects per file, batch 8: one wire ack per file.
    assert_eq!(out.sink.ack_messages, 5);
    assert_eq!(out.source.log_writes, 5);
    env.verify();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);

    let env2 = {
        let mut e = TcpEnv::new("tcp5", 6, 512 << 10);
        e.cfg.ack_batch = 4;
        e.cfg.ack_flush_us = 500;
        e
    };
    let out = env2.run(FaultPlan::at_fraction(0.5, Side::Source), false);
    assert!(!out.completed, "fault should trigger over TCP too");
    let out2 = env2.run(FaultPlan::none(), true);
    assert!(out2.completed, "{:?}", out2.fault);
    env2.verify();
    let _ = std::fs::remove_dir_all(&env2.cfg.ft_dir);
}

#[test]
fn tcp_frame_bytes_are_pinned_for_payload_messages() {
    // Read the raw socket on the far side of a TcpEndpoint and compare
    // every frame byte-for-byte against the hand-built contiguous
    // layout: [u32 len][type][fields][u32 payload len][payload]. The
    // vectored header-scratch + gathered-payload send path must produce
    // EXACTLY the bytes the old frame-alloc path did, for owned and
    // sliced payloads and for control messages.
    let listener = tcp::listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let reader = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut frames = Vec::new();
        for _ in 0..3 {
            let mut len_buf = [0u8; 4];
            s.read_exact(&mut len_buf).unwrap();
            let mut body = vec![0u8; u32::from_le_bytes(len_buf) as usize];
            s.read_exact(&mut body).unwrap();
            let mut frame = len_buf.to_vec();
            frame.extend_from_slice(&body);
            frames.push(frame);
        }
        frames
    });
    let ep = tcp::connect(addr, WireModel::none(), FaultController::unarmed()).unwrap();

    let payload: Vec<u8> = (0..10_000u32).map(|i| (i * 131) as u8).collect();
    // 1: owned payload. 2: the same bytes as a refcounted slice of a
    // padded backing buffer. 3: a control message (header-only path).
    ep.send(Message::NewBlock {
        file_idx: 5,
        block_idx: 6,
        offset: 6 << 16,
        digest: 77,
        data: payload.clone().into(),
    })
    .unwrap();
    let mut backing = vec![0xEEu8; 100];
    backing.extend_from_slice(&payload);
    backing.extend_from_slice(&[0xEE; 100]);
    ep.send(Message::NewBlock {
        file_idx: 5,
        block_idx: 6,
        offset: 6 << 16,
        digest: 77,
        data: Bytes::from_vec(backing).slice(100..100 + payload.len()),
    })
    .unwrap();
    ep.send(Message::FileClose { file_idx: 5 }).unwrap();

    let frames = reader.join().unwrap();

    // Reference frame, field by field.
    let mut body = vec![4u8]; // T_NEW_BLOCK
    body.extend_from_slice(&5u32.to_le_bytes());
    body.extend_from_slice(&6u32.to_le_bytes());
    body.extend_from_slice(&(6u64 << 16).to_le_bytes());
    body.extend_from_slice(&77u64.to_le_bytes());
    body.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    body.extend_from_slice(&payload);
    let mut expect = (body.len() as u32).to_le_bytes().to_vec();
    expect.extend_from_slice(&body);
    assert_eq!(frames[0], expect, "owned-payload frame drifted");
    assert_eq!(frames[1], expect, "sliced-payload frame differs from owned");

    let mut expect_close = 5u32.to_le_bytes().to_vec(); // body = 1 type + 4 idx
    expect_close.push(6); // T_FILE_CLOSE
    expect_close.extend_from_slice(&5u32.to_le_bytes());
    assert_eq!(frames[2], expect_close, "control frame drifted");
}

#[test]
fn tcp_serialization_preserves_large_objects() {
    // One object larger than typical socket buffers (1 MiB) to force
    // multi-read frames.
    let mut cfgd = Config::for_tests("tcp3");
    cfgd.object_size = 1 << 20;
    cfgd.rma_bytes = 8 << 20;
    let env = TcpEnv {
        cfg: cfgd.clone(),
        source: {
            let p = Arc::new(SimPfs::new(cfgd.layout(), cfgd.ost_config(), 1));
            p.populate(&[("big.bin".to_string(), (1 << 20) + 12345)]);
            p
        },
        sink: Arc::new(SimPfs::new(cfgd.layout(), cfgd.ost_config(), 1)),
        files: vec!["big.bin".to_string()],
    };
    let out = env.run(FaultPlan::none(), false);
    assert!(out.completed, "{:?}", out.fault);
    env.verify();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}
