//! Transfer over the TCP transport: the two-process deployment path
//! (source and sink nodes joined by real loopback sockets with full
//! message serialization), exercised in-process.

use std::sync::Arc;

use ftlads::config::Config;
use ftlads::coordinator::{self, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{Mechanism, Method};
use ftlads::net::{tcp, Endpoint, FaultController, Side, WireModel};
use ftlads::pfs::sim::SimPfs;
use ftlads::pfs::Pfs;
use ftlads::workload;

struct TcpEnv {
    cfg: Config,
    source: Arc<SimPfs>,
    sink: Arc<SimPfs>,
    files: Vec<String>,
}

impl TcpEnv {
    fn new(tag: &str, nfiles: usize, size: u64) -> TcpEnv {
        let mut cfg = Config::for_tests(tag);
        cfg.mechanism = Mechanism::Universal;
        cfg.method = Method::Bit64;
        let wl = workload::big_workload(nfiles, size);
        let source = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
        source.populate(&wl.as_tuples());
        let sink = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
        let files = wl.files.iter().map(|f| f.name.clone()).collect();
        TcpEnv { cfg, source, sink, files }
    }

    fn run(&self, fault: FaultPlan, resume: bool) -> coordinator::TransferOutcome {
        let total: u64 = self
            .files
            .iter()
            .map(|n| self.source.lookup(n).unwrap().1.size)
            .sum();
        let ctl = fault.arm(total);
        let (src_ep, sink_ep) =
            tcp::loopback_pair(WireModel::none(), ctl).expect("tcp pair");
        let src_ep: Arc<dyn Endpoint> = Arc::new(src_ep);
        let sink_ep: Arc<dyn Endpoint> = Arc::new(sink_ep);

        let sink_node = coordinator::sink::spawn_sink(
            &self.cfg,
            self.sink.clone() as Arc<dyn Pfs>,
            sink_ep,
            None,
        )
        .expect("spawn sink");
        let spec = TransferSpec { files: self.files.clone(), resume, fault: FaultPlan::none() };
        let src_report = coordinator::source::run_source(
            &self.cfg,
            self.source.clone() as Arc<dyn Pfs>,
            src_ep.clone(),
            &spec,
        )
        .expect("run source");
        let sink_report = sink_node.join();
        let fault_msg = src_report.fault.clone().or(sink_report.fault);
        coordinator::TransferOutcome {
            completed: fault_msg.is_none()
                && src_report.files_done as usize == self.files.len(),
            fault: fault_msg,
            elapsed: std::time::Duration::ZERO,
            source: src_report.counters,
            sink: sink_report.counters,
            log_space: src_report.log_space,
            resources: Default::default(),
            payload_bytes: src_ep.payload_sent(),
            rma_stalls: sink_report.rma_stalls,
            source_sched: src_report.sched,
            sink_sched: sink_report.sched,
            send_window: src_report.send_window,
            ack_batch_effective: sink_report.ack_batch_effective,
        }
    }

    fn verify(&self) {
        for name in &self.files {
            let (_, meta) = self.sink.lookup(name).expect("file at sink");
            assert!(meta.committed, "{name} not committed");
            let objects =
                (meta.size + self.cfg.object_size - 1) / self.cfg.object_size;
            for b in 0..objects {
                let offset = b * self.cfg.object_size;
                let len = (meta.size - offset).min(self.cfg.object_size) as usize;
                let (got, _) = self
                    .sink
                    .written_digest(name, offset)
                    .unwrap_or_else(|| panic!("{name} block {b} missing"));
                assert_eq!(got, self.source.expected_digest(name, offset, len));
            }
        }
    }
}

#[test]
fn tcp_full_transfer() {
    let env = TcpEnv::new("tcp1", 5, 512 << 10);
    let out = env.run(FaultPlan::none(), false);
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.source.objects_synced, 5 * 8);
    env.verify();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn tcp_fault_then_resume() {
    let env = TcpEnv::new("tcp2", 6, 512 << 10);
    let out = env.run(FaultPlan::at_fraction(0.5, Side::Source), false);
    assert!(!out.completed, "fault should trigger over TCP too");
    let out2 = env.run(FaultPlan::none(), true);
    assert!(out2.completed, "{:?}", out2.fault);
    assert!(
        out2.source.objects_skipped_resume + out2.source.files_skipped_resume > 0,
        "resume should reuse progress"
    );
    env.verify();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn tcp_batched_acks_roundtrip_the_codec() {
    // BLOCK_SYNC_BATCH serialized through the real wire codec over
    // loopback sockets: coalescing survives the byte-level path, and a
    // mid-transfer fault still resumes to a verified dataset.
    let mut env = TcpEnv::new("tcp4", 5, 512 << 10);
    env.cfg.ack_batch = 8;
    env.cfg.ack_flush_us = 100_000;
    let out = env.run(FaultPlan::none(), false);
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.source.objects_synced, 5 * 8);
    // 8 objects per file, batch 8: one wire ack per file.
    assert_eq!(out.sink.ack_messages, 5);
    assert_eq!(out.source.log_writes, 5);
    env.verify();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);

    let env2 = {
        let mut e = TcpEnv::new("tcp5", 6, 512 << 10);
        e.cfg.ack_batch = 4;
        e.cfg.ack_flush_us = 500;
        e
    };
    let out = env2.run(FaultPlan::at_fraction(0.5, Side::Source), false);
    assert!(!out.completed, "fault should trigger over TCP too");
    let out2 = env2.run(FaultPlan::none(), true);
    assert!(out2.completed, "{:?}", out2.fault);
    env2.verify();
    let _ = std::fs::remove_dir_all(&env2.cfg.ft_dir);
}

#[test]
fn tcp_serialization_preserves_large_objects() {
    // One object larger than typical socket buffers (1 MiB) to force
    // multi-read frames.
    let mut cfgd = Config::for_tests("tcp3");
    cfgd.object_size = 1 << 20;
    cfgd.rma_bytes = 8 << 20;
    let env = TcpEnv {
        cfg: cfgd.clone(),
        source: {
            let p = Arc::new(SimPfs::new(cfgd.layout(), cfgd.ost_config(), 1));
            p.populate(&[("big.bin".to_string(), (1 << 20) + 12345)]);
            p
        },
        sink: Arc::new(SimPfs::new(cfgd.layout(), cfgd.ost_config(), 1)),
        files: vec!["big.bin".to_string()],
    };
    let out = env.run(FaultPlan::none(), false);
    assert!(out.completed, "{:?}", out.fault);
    env.verify();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}
