//! The `ftlads serve` multi-job daemon: equivalence pins for the session
//! / builder API redesign (a default-config job through every entry
//! point must stay wire- and behavior-identical to the old
//! `run_transfer`), concurrent jobs through one in-process [`Serve`]
//! with per-job FT-log isolation, the shared cross-job OST congestion
//! registry steering the §2.1 schedulers around other jobs' hot OSTs,
//! and the ft_matrix-style leg that kills one job mid-transfer while the
//! daemon and its surviving jobs carry on.
//!
//! Crash consistency (`serve_recover`): a daemon whose jobs all die
//! mid-transfer leaves a durable manifest, and a NEW daemon over the
//! same ft_dir re-admits every incomplete job (watchdog-faulted ones
//! included) within the §5.2.2 resume bound; per-tenant byte quotas
//! (`serve_quota_bytes`) reject over-quota submissions with a
//! per-tenant breakdown; and with the knobs off, nothing of the
//! manifest machinery ever touches disk.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use ftlads::config::Config;
use ftlads::coordinator::serve::{JobRequest, Serve};
use ftlads::coordinator::sink::SinkSession;
use ftlads::coordinator::source::SourceSession;
use ftlads::coordinator::{SimEnv, TransferJob, TransferOutcome, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::metrics::CounterSnapshot;
use ftlads::net::{channel, Endpoint, FaultController, Message, NetError, Side};
use ftlads::pfs::ost::OstId;
use ftlads::pfs::{OstRegistry, Pfs};
use ftlads::workload;

/// Endpoint wrapper recording the encoded bytes of every send — the
/// wire evidence for the entry-point equivalence pins.
struct Recorder {
    inner: channel::ChannelEndpoint,
    sent: Arc<Mutex<Vec<Vec<u8>>>>,
}

impl Recorder {
    fn new(inner: channel::ChannelEndpoint) -> (Recorder, Arc<Mutex<Vec<Vec<u8>>>>) {
        let sent = Arc::new(Mutex::new(Vec::new()));
        (Recorder { inner, sent: sent.clone() }, sent)
    }
}

impl Endpoint for Recorder {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        let mut bytes = Vec::new();
        msg.encode(&mut bytes);
        self.sent.lock().unwrap_or_else(|e| e.into_inner()).push(bytes);
        self.inner.send(msg)
    }

    fn recv(&self) -> Result<Message, NetError> {
        self.inner.recv()
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        self.inner.recv_timeout(timeout)
    }

    fn payload_sent(&self) -> u64 {
        self.inner.payload_sent()
    }
}

/// Sorted copy — IO threads race, so cross-run wire comparison is by
/// multiset (the same convention as the multi-stream byte-identity pin).
fn sorted(trace: &[Vec<u8>]) -> Vec<Vec<u8>> {
    let mut t = trace.to_vec();
    t.sort();
    t
}

/// A counter snapshot with the two scheduling-race-sensitive fields
/// cleared: slot stalls and credit waits depend on thread interleaving,
/// everything else at the default (lockstep) config is deterministic.
fn canon(mut c: CounterSnapshot) -> CounterSnapshot {
    c.send_stalls = 0;
    c.credit_waits = 0;
    c
}

/// Run one transfer over tapped channel endpoints through either the
/// deprecated free functions (`legacy`) or the session API, returning
/// the encoded frames each side sent.
fn tapped_run(cfg: &Config, env: &SimEnv, legacy: bool) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let (src_ep, snk_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
    let (src_tap, src_sent) = Recorder::new(src_ep);
    let (snk_tap, snk_sent) = Recorder::new(snk_ep);
    let spec = TransferSpec::fresh(env.files.clone());
    if legacy {
        #[allow(deprecated)] // this run deliberately pins the wrappers
        {
            let node = ftlads::coordinator::sink::spawn_sink(
                cfg,
                env.sink.clone(),
                Arc::new(snk_tap),
                None,
            )
            .unwrap();
            let src = ftlads::coordinator::source::run_source(
                cfg,
                env.source.clone(),
                Arc::new(src_tap),
                &spec,
            )
            .unwrap();
            assert!(src.fault.is_none(), "{:?}", src.fault);
            let snk = node.join();
            assert!(snk.fault.is_none(), "{:?}", snk.fault);
        }
    } else {
        let node = SinkSession::new(cfg, env.sink.clone(), Arc::new(snk_tap))
            .spawn()
            .unwrap();
        let src = SourceSession::new(cfg, env.source.clone(), Arc::new(src_tap))
            .run(&spec)
            .unwrap();
        assert!(src.fault.is_none(), "{:?}", src.fault);
        let snk = node.join();
        assert!(snk.fault.is_none(), "{:?}", snk.fault);
    }
    let a = src_sent.lock().unwrap_or_else(|e| e.into_inner()).clone();
    let b = snk_sent.lock().unwrap_or_else(|e| e.into_inner()).clone();
    (a, b)
}

fn default_job(env: &SimEnv) -> JobRequest {
    JobRequest {
        spec: TransferSpec::fresh(env.files.clone()),
        source_pfs: env.source.clone() as Arc<dyn Pfs>,
        sink_pfs: env.sink.clone() as Arc<dyn Pfs>,
        runtime: None,
    }
}

/// Objects already durable in job `id`'s FT log under `cfg.ft_dir` —
/// the `logged` term of the §5.2.2 bound `resent <= total - logged`.
fn logged_objects(cfg: &Config, id: u64) -> u64 {
    let mut ft = cfg.ft();
    ft.dir = cfg.ft_dir.join(format!("job-{id}"));
    ftlads::ftlog::recover::recover_all(&ft)
        .unwrap()
        .values()
        .map(|s| s.count() as u64)
        .sum()
}

#[test]
fn session_wire_bytes_match_deprecated_entry_points() {
    // The tap-based equivalence pin: at the default config the session
    // API must put EXACTLY the bytes of the legacy free functions on the
    // wire, in both directions, starting with the pinned seed CONNECT
    // (no trailing job / send_window / data_streams fields).
    let cfg = Config::for_tests("serve-wire-pin");
    let wl = workload::big_workload(4, 512 << 10); // 32 objects
    let env_a = SimEnv::new(cfg.clone(), &wl);
    let (src_a, snk_a) = tapped_run(&cfg, &env_a, true);
    env_a.verify_sink_complete().unwrap();
    let env_b = SimEnv::new(cfg.clone(), &wl);
    let (src_b, snk_b) = tapped_run(&cfg, &env_b, false);
    env_b.verify_sink_complete().unwrap();

    // Hand-built fused CONNECT: the seed layout, byte for byte — a job
    // tag (or any other trailing field) at the defaults would break it.
    let mut connect = vec![0u8]; // T_CONNECT
    connect.extend_from_slice(&cfg.object_size.to_le_bytes());
    connect.extend_from_slice(&8u32.to_le_bytes()); // 8 RMA slots in tests
    connect.push(0); // resume = false
    connect.extend_from_slice(&1u32.to_le_bytes()); // ack_batch = 1
    assert_eq!(src_a[0], connect, "legacy CONNECT drifted from the seed bytes");
    assert_eq!(src_b[0], connect, "session CONNECT drifted from the seed bytes");
    assert_eq!(
        sorted(&src_a),
        sorted(&src_b),
        "session API changed the source->sink wire bytes"
    );
    assert_eq!(
        sorted(&snk_a),
        sorted(&snk_b),
        "session API changed the sink->source wire bytes"
    );
    let _ = std::fs::remove_dir_all(&env_a.cfg.ft_dir);
    let _ = std::fs::remove_dir_all(&env_b.cfg.ft_dir);
}

#[test]
#[allow(deprecated)] // the baseline run deliberately pins run_transfer
fn builder_and_serve_outcomes_match_run_transfer() {
    // One default-config job through all three entry points — the
    // deprecated `run_transfer`, the `TransferJob` builder, and a
    // single-job `Serve` daemon — must produce identical outcomes
    // (every deterministic counter, negotiated knob and byte total).
    let wl = workload::mixed_workload(6, 256 << 10, 11);
    let run = |out: TransferOutcome, env: &SimEnv| -> TransferOutcome {
        assert!(out.completed, "{:?}", out.fault);
        env.verify_sink_complete().unwrap();
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
        out
    };

    let env_a = SimEnv::new(Config::for_tests("serve-eq-legacy"), &wl);
    let out_a = run(
        ftlads::coordinator::run_transfer(
            &env_a.cfg,
            env_a.source.clone(),
            env_a.sink.clone(),
            &TransferSpec::fresh(env_a.files.clone()),
            None,
        )
        .unwrap(),
        &env_a,
    );

    let env_b = SimEnv::new(Config::for_tests("serve-eq-builder"), &wl);
    let out_b = run(
        TransferJob::builder(&env_b.cfg, &TransferSpec::fresh(env_b.files.clone()))
            .source_pfs(env_b.source.clone())
            .sink_pfs(env_b.sink.clone())
            .run()
            .unwrap(),
        &env_b,
    );

    let env_c = SimEnv::new(Config::for_tests("serve-eq-daemon"), &wl);
    let serve = Serve::new(env_c.cfg.clone());
    let handle = serve.submit("tenant", 1, default_job(&env_c)).unwrap();
    let out_c = handle.wait().unwrap();
    serve.drain();
    assert_eq!(serve.stats().jobs_completed, 1);
    // The daemon job logs under its own namespace...
    assert!(env_c.cfg.ft_dir.join("job-1").is_dir(), "job FT namespace missing");
    // ...and with `serve_recover` at its default (off) the manifest
    // machinery never touches disk — startup is seed-identical.
    assert!(
        !env_c.cfg.ft_dir.join("manifest").exists(),
        "recover-off daemon must not create a manifest dir"
    );
    let out_c = run(out_c, &env_c);

    for (label, out) in [("builder", &out_b), ("serve", &out_c)] {
        assert_eq!(canon(out.source), canon(out_a.source), "{label} source counters");
        assert_eq!(canon(out.sink), canon(out_a.sink), "{label} sink counters");
        assert_eq!(out.payload_bytes, out_a.payload_bytes, "{label} payload bytes");
        assert_eq!(out.send_window, out_a.send_window, "{label}");
        assert_eq!(out.send_window_effective, out_a.send_window_effective, "{label}");
        assert_eq!(out.ack_batch_effective, out_a.ack_batch_effective, "{label}");
        assert_eq!(out.rma_bytes_effective, out_a.rma_bytes_effective, "{label}");
        assert_eq!(out.data_streams, out_a.data_streams, "{label}");
        assert_eq!(out.source_sched.picks, out_a.source_sched.picks, "{label}");
        assert_eq!(out.sink_sched.picks, out_a.sink_sched.picks, "{label}");
        assert_eq!(out.fault, out_a.fault, "{label}");
        // A lone job sees no foreign load: the shared registry must not
        // change a single scheduling decision.
        assert_eq!(out.source_sched.shared_picks, 0, "{label}");
        assert_eq!(out.sink_sched.shared_picks, 0, "{label}");
    }
}

#[test]
fn concurrent_jobs_match_sequential_and_isolate_logs() {
    // N jobs through one daemon concurrently == the same N jobs run
    // sequentially through the builder, job for job — and each job's FT
    // object log lands in its own `job-<id>` namespace.
    let workloads: Vec<_> =
        (0..3u64).map(|j| workload::mixed_workload(4, 256 << 10, 20 + j)).collect();

    // Sequential baseline, one isolated env per job.
    let mut baseline = Vec::new();
    for (j, wl) in workloads.iter().enumerate() {
        let env = SimEnv::new(Config::for_tests(&format!("serve-seq-{j}")), wl);
        let out = TransferJob::builder(&env.cfg, &TransferSpec::fresh(env.files.clone()))
            .source_pfs(env.source.clone())
            .sink_pfs(env.sink.clone())
            .run()
            .unwrap();
        assert!(out.completed, "sequential {j}: {:?}", out.fault);
        env.verify_sink_complete().unwrap();
        baseline.push(out);
        let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    }

    // The same jobs, all in flight through one daemon.
    let mut cfg = Config::for_tests("serve-conc");
    cfg.serve_max_jobs = 3;
    let serve = Serve::new(cfg.clone());
    let envs: Vec<_> =
        workloads.iter().map(|wl| SimEnv::new(cfg.clone(), wl)).collect();
    let handles: Vec<_> = envs
        .iter()
        .map(|env| serve.submit("tenant", 1, default_job(env)).unwrap())
        .collect();
    let ids: Vec<u64> = handles.iter().map(|h| h.id()).collect();
    let outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    serve.drain();

    for (j, (out, env)) in outs.iter().zip(&envs).enumerate() {
        assert!(out.completed, "concurrent {j}: {:?}", out.fault);
        env.verify_sink_complete().unwrap();
        assert_eq!(canon(out.source), canon(baseline[j].source), "job {j} source");
        assert_eq!(canon(out.sink), canon(baseline[j].sink), "job {j} sink");
        assert_eq!(out.payload_bytes, baseline[j].payload_bytes, "job {j}");
        // Per-job FT namespace: each job logged under its own id...
        let dir = cfg.ft_dir.join(format!("job-{}", ids[j]));
        assert!(dir.is_dir(), "job {} has no FT namespace {}", j, dir.display());
    }
    // ...and the ids are distinct by construction.
    let mut unique = ids.clone();
    unique.sort_unstable();
    unique.dedup();
    assert_eq!(unique.len(), ids.len(), "job ids collided: {ids:?}");

    let stats = serve.stats();
    assert_eq!(stats.jobs_submitted, 3);
    assert_eq!(stats.jobs_admitted, 3);
    assert_eq!(stats.jobs_completed, 3);
    assert_eq!(stats.jobs_faulted, 0);
    assert!(stats.peak_concurrent <= 3);
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}

#[test]
fn admission_cap_holds_and_drain_rejects_new_jobs() {
    let mut cfg = Config::for_tests("serve-cap");
    cfg.serve_max_jobs = 1;
    let serve = Serve::new(cfg.clone());
    let wl = workload::big_workload(2, 256 << 10);
    let envs: Vec<_> = (0..3).map(|_| SimEnv::new(cfg.clone(), &wl)).collect();
    let handles: Vec<_> = envs
        .iter()
        .map(|env| serve.submit("tenant", 1, default_job(env)).unwrap())
        .collect();
    for h in handles {
        assert!(h.wait().unwrap().completed);
    }
    serve.drain();
    let stats = serve.stats();
    assert_eq!(stats.jobs_completed, 3);
    assert_eq!(stats.peak_concurrent, 1, "one admission slot must serialize");
    // Drained daemon: further submissions are refused and counted.
    let env = SimEnv::new(cfg.clone(), &wl);
    assert!(serve.submit("tenant", 1, default_job(&env)).is_err());
    assert_eq!(serve.stats().jobs_rejected, 1);
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}

#[test]
fn foreign_charges_steer_scheduler_deterministically() {
    // The steering acceptance pin, deterministically: a phantom second
    // job saturates OSTs 0..=4 on a shared registry; a real job running
    // with a handle on that registry must (a) see the foreign load at
    // pick time and (b) steer its congestion-aware picks onto OSTs the
    // phantom job is NOT hammering.
    let cfg = Config::for_tests("serve-steer-unit");
    let wl = workload::big_workload(12, 512 << 10); // files across all 11 OSTs
    let env = SimEnv::new(cfg.clone(), &wl);
    let registry = OstRegistry::new(cfg.ost_count);
    let other = registry.handle();
    for o in 0..5u32 {
        for _ in 0..64 {
            other.begin(OstId(o));
        }
    }
    let out = TransferJob::builder(&cfg, &TransferSpec::fresh(env.files.clone()))
        .source_pfs(env.source.clone())
        .sink_pfs(env.sink.clone())
        .shared_source_osts(Arc::new(registry.handle()))
        .shared_sink_osts(Arc::new(registry.handle()))
        .run()
        .unwrap();
    assert!(out.completed, "{:?}", out.fault);
    env.verify_sink_complete().unwrap();
    let picks = out.source_sched.shared_picks + out.sink_sched.shared_picks;
    let avoids = out.source_sched.shared_avoids + out.sink_sched.shared_avoids;
    assert!(picks > 0, "foreign load on half the OSTs never reached a pick");
    assert!(
        avoids > 0,
        "{picks} foreign-load picks but not one steered to an un-hammered OST"
    );
    // The job's own charges drained with its handles: nothing but the
    // phantom's load is left on the registry.
    assert_eq!(registry.total_load(), 5 * 64);
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);

    // Registry-blind control: the same transfer without a handle makes
    // purely local decisions — no foreign-aware picks can exist.
    let cfg2 = Config::for_tests("serve-steer-blind");
    let env2 = SimEnv::new(cfg2.clone(), &wl);
    let out2 = TransferJob::builder(&cfg2, &TransferSpec::fresh(env2.files.clone()))
        .source_pfs(env2.source.clone())
        .sink_pfs(env2.sink.clone())
        .run()
        .unwrap();
    assert!(out2.completed, "{:?}", out2.fault);
    assert_eq!(out2.source_sched.shared_picks, 0);
    assert_eq!(out2.source_sched.shared_avoids, 0);
    assert_eq!(out2.sink_sched.shared_picks, 0);
    let _ = std::fs::remove_dir_all(&cfg2.ft_dir);
}

#[test]
fn two_overlapping_jobs_share_congestion_through_the_daemon() {
    // End to end through `Serve`: two storage-bound jobs overlap in real
    // time on slow strictly-serial OSTs. With `serve_registry` on, each
    // job's scheduler must consult (and steer around) the other's
    // in-flight load; with it off, the same two jobs run registry-blind.
    for informed in [true, false] {
        let mut cfg = Config::for_tests(&format!("serve-steer-e2e-{informed}"));
        cfg.serve_max_jobs = 2;
        cfg.serve_registry = informed;
        cfg.time_scale = 1.0;
        cfg.net_bandwidth = 1e12;
        cfg.net_latency_us = 0;
        cfg.ost_bandwidth = 1e12;
        cfg.ost_latency_us = 200;
        cfg.ost_concurrent = 1;
        cfg.send_window = 16;
        cfg.rma_bytes = 16 * cfg.object_size as usize;
        let serve = Serve::new(cfg.clone());
        let wl = workload::big_workload(6, 512 << 10); // 48 objects each
        let envs: Vec<_> = (0..2).map(|_| SimEnv::new(cfg.clone(), &wl)).collect();
        let handles: Vec<_> = envs
            .iter()
            .map(|env| serve.submit("tenant", 1, default_job(env)).unwrap())
            .collect();
        let outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        serve.drain();
        let mut picks = 0u64;
        let mut avoids = 0u64;
        for (out, env) in outs.iter().zip(&envs) {
            assert!(out.completed, "informed={informed}: {:?}", out.fault);
            env.verify_sink_complete().unwrap();
            picks += out.source_sched.shared_picks + out.sink_sched.shared_picks;
            avoids += out.source_sched.shared_avoids + out.sink_sched.shared_avoids;
        }
        // Jobs done → every handle dropped → no phantom load remains.
        assert_eq!(serve.source_registry().total_load(), 0);
        assert_eq!(serve.sink_registry().total_load(), 0);
        if informed {
            assert!(picks > 0, "overlapping jobs never saw each other's load");
            assert!(avoids > 0, "{picks} foreign-load picks, zero steers");
        } else {
            assert_eq!(picks, 0, "serve_registry=off must be registry-blind");
            assert_eq!(avoids, 0);
        }
        let _ = std::fs::remove_dir_all(&cfg.ft_dir);
    }
}

#[test]
fn killed_job_leaves_daemon_and_survivors_intact_then_resumes() {
    // ft_matrix-style leg at the daemon level: three jobs, the middle
    // one's leg is killed mid-transfer. The survivors and the daemon
    // must be unaffected; the killed job then resumes FROM ITS OWN
    // job-scoped log and finishes without re-sending what it synced.
    let mut cfg = Config::for_tests("serve-kill");
    cfg.serve_max_jobs = 3;
    let serve = Serve::new(cfg.clone());
    let workloads: Vec<_> =
        (0..3u64).map(|j| workload::mixed_workload(5, 256 << 10, 40 + j)).collect();
    let envs: Vec<_> =
        workloads.iter().map(|wl| SimEnv::new(cfg.clone(), wl)).collect();
    let handles: Vec<_> = envs
        .iter()
        .enumerate()
        .map(|(j, env)| {
            let mut req = default_job(env);
            if j == 1 {
                req.spec =
                    req.spec.with_fault(FaultPlan::at_fraction(0.5, Side::Source));
            }
            serve.submit("tenant", 1, req).unwrap()
        })
        .collect();
    let killed_id = handles[1].id();
    let outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();

    assert!(outs[0].completed, "survivor 0: {:?}", outs[0].fault);
    assert!(outs[2].completed, "survivor 2: {:?}", outs[2].fault);
    assert!(!outs[1].completed, "the fault plan must kill job 1's leg");
    assert!(outs[1].fault.is_some());
    envs[0].verify_sink_complete().unwrap();
    envs[2].verify_sink_complete().unwrap();

    // The daemon itself is unaffected: counters add up and it still
    // takes (and completes) new work.
    let stats = serve.stats();
    assert_eq!(stats.jobs_faulted, 1);
    assert_eq!(stats.jobs_completed, 2);
    let extra_env = SimEnv::new(cfg.clone(), &workloads[0]);
    let extra = serve.submit("tenant", 1, default_job(&extra_env)).unwrap();
    assert!(extra.wait().unwrap().completed, "daemon must keep serving");
    serve.drain();
    assert_eq!(serve.stats().jobs_completed, 3);

    // Resume the killed transfer against its own namespace: same base
    // config, same job id → the builder re-derives `<ft_dir>/job-<id>`
    // and §5.2.2 recovery skips everything that job already synced.
    let out = TransferJob::builder(
        &cfg,
        &TransferSpec::resuming(envs[1].files.clone()),
    )
    .source_pfs(envs[1].source.clone())
    .sink_pfs(envs[1].sink.clone())
    .job_id(killed_id)
    .run()
    .unwrap();
    assert!(out.completed, "resume: {:?}", out.fault);
    assert!(
        out.source.objects_skipped_resume + out.source.files_skipped_resume > 0,
        "resume must reuse the killed job's own log, not start over"
    );
    envs[1].verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}

#[test]
fn daemon_kill_recovers_all_jobs_from_manifest() {
    // The crash-consistency tentpole, in-process: a `serve_recover`
    // daemon accepts three jobs that ALL die mid-transfer (the stand-in
    // for SIGKILL-ing the daemon — every job incomplete, only the
    // manifest and the per-job FT logs surviving on disk). A NEW daemon
    // over the same ft_dir replays the manifest, re-admits every
    // incomplete job under its ORIGINAL id with resume forced, and each
    // finishes byte-exact within the §5.2.2 bound.
    let mut cfg = Config::for_tests("serve-manifest-recover");
    cfg.serve_recover = true;
    cfg.serve_max_jobs = 3;
    let workloads: Vec<_> =
        (0..3u64).map(|j| workload::mixed_workload(5, 256 << 10, 60 + j)).collect();
    let envs: Vec<_> =
        workloads.iter().map(|wl| SimEnv::new(cfg.clone(), wl)).collect();
    let serve = Serve::new(cfg.clone());
    let handles: Vec<_> = envs
        .iter()
        .map(|env| {
            let mut req = default_job(env);
            req.spec = req.spec.with_fault(FaultPlan::at_fraction(0.5, Side::Source));
            serve.submit("tenant", 1, req).unwrap()
        })
        .collect();
    let ids: Vec<u64> = handles.iter().map(|h| h.id()).collect();
    for h in handles {
        assert!(!h.wait().unwrap().completed, "every job must die mid-transfer");
    }
    serve.drain();
    drop(serve); // the "killed" daemon

    // What the crash left on disk: per-job logged objects + a manifest
    // whose latest word on every job is non-terminal (FAULTED).
    let logged: Vec<u64> = ids.iter().map(|&id| logged_objects(&cfg, id)).collect();
    assert!(logged.iter().any(|&l| l > 0), "nothing was logged before the kill");
    let replay = ftlads::ftlog::manifest::replay(&cfg.ft_dir).unwrap();
    assert_eq!(replay.incomplete().count(), 3, "all three jobs incomplete");

    // Restart: replay the manifest, rebuild each job's endpoints, let
    // the daemon re-admit the lot through the fair-share path.
    let serve2 = Serve::new(cfg.clone());
    let recovered = serve2
        .recover(|job| {
            let i = ids.iter().position(|&id| id == job.id).unwrap();
            assert_eq!(job.tenant, "tenant");
            assert_eq!(job.logged_objects, logged[i], "job {i} logged count");
            Some(default_job(&envs[i])) // resume=false here: recover forces it
        })
        .unwrap();
    assert_eq!(recovered.len(), 3);
    let stats = serve2.stats();
    assert_eq!(stats.jobs_recovered, 3);
    assert_eq!(stats.jobs_submitted, 0, "recovered jobs are not submissions");
    assert!(stats.manifest_records >= 9, "3 jobs x SUBMITTED/ADMITTED/FAULTED");
    for h in recovered {
        let id = h.id();
        let i = ids.iter().position(|&x| x == id).unwrap();
        let out = h.wait().unwrap();
        assert!(out.completed, "recovered job {id}: {:?}", out.fault);
        // §5.2.2 across the daemon kill: only the complement is resent.
        let total = workloads[i].total_objects(cfg.object_size);
        assert!(
            out.source.objects_sent <= total - logged[i],
            "job {id}: resent {} > total {} - logged {}",
            out.source.objects_sent,
            total,
            logged[i]
        );
        envs[i].verify_sink_complete().unwrap();
    }
    // A fresh submission on the recovered daemon never recycles an id.
    let extra_env = SimEnv::new(cfg.clone(), &workloads[0]);
    let extra = serve2.submit("tenant", 1, default_job(&extra_env)).unwrap();
    assert!(ids.iter().all(|&id| id != extra.id()), "job id recycled: {ids:?}");
    assert!(extra.wait().unwrap().completed);
    serve2.drain();
    // The manifest's last word on every job is now COMPLETED.
    let replay = ftlads::ftlog::manifest::replay(&cfg.ft_dir).unwrap();
    assert_eq!(replay.incomplete().count(), 0, "recovery must complete the story");
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}

#[test]
fn watchdog_faulted_job_leaves_manifest_record_and_recovers() {
    // Satellite: a job the `job_deadline_ms` watchdog shoots leaves a
    // FAULTED manifest record, and `Serve::recover` re-admits it like
    // any other incomplete job. Slow strictly-serial OSTs make the
    // transfer take far longer than the 1 ms deadline, so the watchdog
    // fires deterministically; the detached body's own fault plan kills
    // it shortly after, so the zombie is long gone before recovery.
    let mut cfg = Config::for_tests("serve-watchdog-manifest");
    cfg.serve_recover = true;
    cfg.job_deadline_ms = 1;
    cfg.time_scale = 1.0;
    cfg.ost_latency_us = 2_000;
    cfg.ost_concurrent = 1;
    let wl = workload::big_workload(4, 256 << 10);
    let env = SimEnv::new(cfg.clone(), &wl);
    let serve = Serve::new(cfg.clone());
    let mut req = default_job(&env);
    req.spec = req.spec.with_fault(FaultPlan::at_fraction(0.5, Side::Source));
    let handle = serve.submit("tenant", 1, req).unwrap();
    let id = handle.id();
    let err = handle.wait().expect_err("watchdog must fault the silent job");
    assert!(err.to_string().contains("job_deadline_ms"), "{err:#}");
    serve.drain();
    assert_eq!(serve.stats().jobs_faulted, 1);
    drop(serve);
    // Let the detached body hit its own fault point and exit before the
    // recovery run reuses its PFS handles.
    std::thread::sleep(Duration::from_millis(800));

    let replay = ftlads::ftlog::manifest::replay(&cfg.ft_dir).unwrap();
    let rec = replay.jobs.get(&id).expect("watchdog job missing from manifest");
    assert_eq!(rec.state, ftlads::ftlog::manifest::JobState::Faulted);

    // Recovery re-admits the watchdog victim (deadline off this time —
    // the FT knobs the digest pins are unchanged) and it completes.
    let mut cfg2 = cfg.clone();
    cfg2.job_deadline_ms = 0;
    let serve2 = Serve::new(cfg2.clone());
    let recovered = serve2.recover(|_| Some(default_job(&env))).unwrap();
    assert_eq!(recovered.len(), 1);
    assert_eq!(serve2.stats().jobs_recovered, 1);
    for h in recovered {
        assert!(h.wait().unwrap().completed, "recovered watchdog job must finish");
    }
    serve2.drain();
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}

#[test]
fn tenant_quota_rejects_over_quota_jobs_with_breakdown() {
    // Satellite: `serve_quota_bytes` caps each tenant's cumulative
    // source bytes. The 4-file 1 MiB workload weighs in at 1 MiB per
    // job; a 1.5 MiB quota admits each tenant's first job and rejects
    // the second, counted per tenant in the snapshot breakdown.
    let mut cfg = Config::for_tests("serve-quota");
    cfg.serve_quota_bytes = 3 << 19; // 1.5 MiB
    let wl = workload::big_workload(4, 256 << 10); // 1 MiB per job
    let serve = Serve::new(cfg.clone());
    let env_a = SimEnv::new(cfg.clone(), &wl);
    let a1 = serve.submit("alice", 1, default_job(&env_a)).unwrap();
    assert!(a1.wait().unwrap().completed);
    let env_a2 = SimEnv::new(cfg.clone(), &wl);
    let err = serve
        .submit("alice", 1, default_job(&env_a2))
        .expect_err("second 1 MiB job must blow alice's 1.5 MiB quota");
    assert!(err.to_string().contains("serve_quota_bytes"), "{err:#}");
    // Quotas are per tenant: bob's first job still fits.
    let env_b = SimEnv::new(cfg.clone(), &wl);
    let b1 = serve.submit("bob", 1, default_job(&env_b)).unwrap();
    assert!(b1.wait().unwrap().completed);
    let env_b2 = SimEnv::new(cfg.clone(), &wl);
    assert!(serve.submit("bob", 1, default_job(&env_b2)).is_err());
    serve.drain();
    let stats = serve.stats();
    assert_eq!(stats.jobs_completed, 2);
    assert_eq!(stats.jobs_rejected, 2);
    assert_eq!(
        stats.rejected_by_tenant,
        vec![("alice".to_string(), 1), ("bob".to_string(), 1)]
    );
    // The quota knob never armed the manifest: nothing under ft_dir
    // but the per-job FT namespaces.
    assert!(!cfg.ft_dir.join("manifest").exists());
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}
