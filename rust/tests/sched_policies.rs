//! Integration tests for the pluggable OST scheduling layer
//! (`ftlads::sched`): every policy drives a full transfer to a verified
//! sink, source and sink can run different policies, and the extracted
//! `CongestionAware` policy is pick-for-pick identical to the seed's
//! hardcoded `pop_least_congested` scheduler.

use ftlads::config::Config;
use ftlads::coordinator::queues::OstQueues;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::pfs::ost::{OstConfig, OstId, OstModel};
use ftlads::sched::{CongestionAware, SchedPolicy};
use ftlads::workload;

fn idle_model(n: u32) -> OstModel {
    OstModel::new(n, OstConfig { time_scale: 0.0, ..Default::default() })
}

fn cleanup(env: &SimEnv) {
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn every_policy_completes_and_verifies() {
    for policy in SchedPolicy::ALL {
        let mut cfg = Config::for_tests(&format!("sched-{}", policy.as_str()));
        cfg.scheduler = policy;
        cfg.sink_scheduler = Some(policy);
        let wl = workload::big_workload(4, 512 << 10); // 32 objects @ 64 KiB
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
        assert!(out.completed, "{}: {:?}", policy.as_str(), out.fault);
        assert_eq!(out.source.objects_synced, 32, "policy {}", policy.as_str());
        env.verify_sink_complete().unwrap();
        cleanup(&env);
    }
}

#[test]
fn mixed_source_sink_policies_complete() {
    // Asymmetric setup: congestion-aware reads, round-robin writes.
    let mut cfg = Config::for_tests("sched-mixed");
    cfg.scheduler = SchedPolicy::CongestionAware;
    cfg.sink_scheduler = Some(SchedPolicy::RoundRobin);
    let wl = workload::mixed_workload(6, 256 << 10, cfg.seed);
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    env.verify_sink_complete().unwrap();
    cleanup(&env);
}

#[test]
fn every_policy_survives_fault_and_resume() {
    use ftlads::fault::FaultPlan;
    use ftlads::net::Side;
    for policy in SchedPolicy::ALL {
        let mut cfg = Config::for_tests(&format!("sched-rec-{}", policy.as_str()));
        cfg.scheduler = policy;
        let wl = workload::big_workload(6, 512 << 10);
        let env = SimEnv::new(cfg, &wl);
        let out = env
            .run(
                &TransferSpec::fresh(env.files.clone())
                    .with_fault(FaultPlan::at_fraction(0.4, Side::Source)),
            )
            .unwrap();
        assert!(!out.completed, "policy {}", policy.as_str());
        let out2 = env.run(&TransferSpec::resuming(env.files.clone())).unwrap();
        assert!(out2.completed, "{}: {:?}", policy.as_str(), out2.fault);
        env.verify_sink_complete().unwrap();
        cleanup(&env);
    }
}

#[test]
fn congestion_aware_matches_seed_pick_sequence() {
    // Fixed synthetic workload over 5 OSTs against an idle model: the
    // extracted CongestionAware policy must dequeue in exactly the order
    // the seed's hardcoded pop_least_congested produced. The reference
    // sequence comes from an inline reimplementation of the seed's
    // selection — `min_by_key((queue_depth, usize::MAX - len, id))` over
    // non-empty queues, verbatim from the pre-refactor queues.rs — NOT
    // from the (now wrapper) pop_least_congested, so a regression in the
    // extracted policy cannot hide by shifting both sequences together.
    use std::collections::VecDeque;
    let m = idle_model(5);
    let arrivals: [(u32, u32); 8] =
        [(0, 0), (2, 1), (2, 2), (4, 3), (1, 4), (2, 5), (0, 6), (4, 7)];

    let mut seed_queues: Vec<VecDeque<u32>> = vec![VecDeque::new(); 5];
    for (ost, item) in arrivals {
        seed_queues[ost as usize].push_back(item);
    }
    let mut seed_seq = Vec::new();
    loop {
        let pick = seed_queues
            .iter()
            .enumerate()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(i, q)| {
                (m.queue_depth(OstId(*i as u32)), usize::MAX - q.len(), *i)
            })
            .map(|(i, _)| i);
        let Some(i) = pick else { break };
        let item = seed_queues[i].pop_front().unwrap();
        seed_seq.push((OstId(i as u32), item));
    }

    let policy_path: OstQueues<u32> = OstQueues::new(5);
    for (ost, item) in arrivals {
        policy_path.push(OstId(ost), item);
    }
    policy_path.close();
    let mut policy_seq = Vec::new();
    while let Some(x) = policy_path.pop_next(&CongestionAware, &m) {
        policy_seq.push(x);
    }

    assert_eq!(policy_seq, seed_seq);
    // Sanity-pin the reference itself: on an idle fleet the seed order is
    // deeper backlog first, ties by lowest OST id.
    let expect = vec![
        (OstId(2), 1),
        (OstId(0), 0),
        (OstId(2), 2),
        (OstId(4), 3),
        (OstId(0), 6),
        (OstId(1), 4),
        (OstId(2), 5),
        (OstId(4), 7),
    ];
    assert_eq!(seed_seq, expect);

    // And the seed-compatible wrapper delegates to the same policy.
    let wrapper_path: OstQueues<u32> = OstQueues::new(5);
    for (ost, item) in arrivals {
        wrapper_path.push(OstId(ost), item);
    }
    wrapper_path.close();
    let mut wrapper_seq = Vec::new();
    while let Some(x) = wrapper_path.pop_least_congested(&m) {
        wrapper_seq.push(x);
    }
    assert_eq!(wrapper_seq, seed_seq);
}

#[test]
fn congestion_aware_outcome_matches_seed_counters() {
    // The default config runs CongestionAware; on the smoke-test workload
    // the transfer outcome must be exactly what the seed produced: all 32
    // objects sent and synced once, 4 files completed, nothing failing
    // verification or skipped.
    let cfg = Config::for_tests("sched-seedeq");
    assert_eq!(cfg.scheduler, SchedPolicy::CongestionAware);
    let wl = workload::big_workload(4, 512 << 10);
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.source.objects_sent, 32);
    assert_eq!(out.source.objects_synced, 32);
    assert_eq!(out.source.files_completed, 4);
    assert_eq!(out.source.objects_skipped_resume, 0);
    assert_eq!(out.sink.objects_failed_verify, 0);
    env.verify_sink_complete().unwrap();
    cleanup(&env);
}

#[test]
fn straggler_policy_avoids_loaded_ost_under_congestion() {
    use ftlads::pfs::Pfs;
    // With a heavily loaded OST and real (scaled) service times, the
    // straggler-aware source must still complete and verify — the EWMA
    // path (on_complete feedback) is exercised end to end.
    let mut cfg = Config::for_tests("sched-strag");
    cfg.scheduler = SchedPolicy::StragglerAware;
    cfg.time_scale = 0.2;
    let wl = workload::big_workload(6, 256 << 10);
    let env = SimEnv::new(cfg, &wl);
    Pfs::ost_model(&*env.source).set_external_load(OstId(1), 8.0);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    env.verify_sink_complete().unwrap();
    cleanup(&env);
}
