//! Unified online autotuner (`--tune`): the OFF default is seed-exact —
//! the CONNECT handshake carries the configured (not the raised) knob
//! values, no tuner thread runs, and every tune field in the outcome is
//! inert — while ON negotiates the full caps, runs one goodput-driven
//! controller per side, and reports the walk in the outcome.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use ftlads::config::Config;
use ftlads::coordinator::sink::SinkSession;
use ftlads::coordinator::source::SourceSession;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::net::{channel, Endpoint, FaultController, Message, NetError};
use ftlads::workload;

/// Endpoint wrapper recording the encoded bytes of every source send —
/// the wire evidence for the seed-exactness pin.
struct Recorder {
    inner: channel::ChannelEndpoint,
    sent: Arc<Mutex<Vec<Vec<u8>>>>,
}

impl Endpoint for Recorder {
    fn send(&self, msg: Message) -> Result<(), NetError> {
        let mut bytes = Vec::new();
        msg.encode(&mut bytes);
        self.sent.lock().unwrap_or_else(|e| e.into_inner()).push(bytes);
        self.inner.send(msg)
    }

    fn recv(&self) -> Result<Message, NetError> {
        self.inner.recv()
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Message, NetError> {
        self.inner.recv_timeout(timeout)
    }

    fn payload_sent(&self) -> u64 {
        self.inner.payload_sent()
    }
}

#[test]
fn tune_off_is_seed_exact_on_the_wire_and_in_the_outcome() {
    // The acceptance pin: with `tune` off (the default) the handshake is
    // byte-identical to the pre-tuner wire — the raised negotiation caps
    // must never leak into a CONNECT unless --tune asked for them.
    let cfg = Config::for_tests("autotune-off-pin");
    assert!(!cfg.tune, "tune must default off");
    assert_eq!(cfg.send_window, 1);
    assert_eq!(cfg.ack_batch, 1);
    let wl = workload::big_workload(4, 512 << 10); // 32 objects
    let env = SimEnv::new(cfg.clone(), &wl);

    let (src_ep, snk_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
    let sent = Arc::new(Mutex::new(Vec::new()));
    let rec = Recorder { inner: src_ep, sent: sent.clone() };
    let node = SinkSession::new(&cfg, env.sink.clone(), Arc::new(snk_ep))
        .spawn()
        .unwrap();
    let src = SourceSession::new(&cfg, env.source.clone(), Arc::new(rec))
        .run(&TransferSpec::fresh(env.files.clone()))
        .unwrap();
    let snk = node.join();
    assert!(src.fault.is_none(), "{:?}", src.fault);
    assert!(snk.fault.is_none(), "{:?}", snk.fault);
    env.verify_sink_complete().unwrap();

    // Hand-built fused CONNECT: no raised ack_batch, no trailing
    // send_window or data_streams field (both at their omit-at-default
    // value of 1) — exactly the seed bytes.
    let mut connect = vec![0u8]; // T_CONNECT
    connect.extend_from_slice(&cfg.object_size.to_le_bytes());
    connect.extend_from_slice(&8u32.to_le_bytes()); // 8 RMA slots in tests
    connect.push(0); // resume = false
    connect.extend_from_slice(&1u32.to_le_bytes()); // ack_batch = 1
    let sent = sent.lock().unwrap_or_else(|e| e.into_inner()).clone();
    assert_eq!(sent[0], connect, "tune-off CONNECT grew beyond the seed bytes");
    assert!(
        sent.iter().all(|f| f.first() != Some(&10u8)),
        "STREAM_HELLO on a tune-off single-stream session"
    );

    // No tuner ran: every tune signal in the reports is inert.
    assert_eq!(src.counters.tune_epochs, 0);
    assert_eq!(snk.counters.tune_epochs, 0);
    assert_eq!(src.goodput_final, 0.0);
    assert!(src.tune_trajectory.is_empty());
    assert!(snk.tune_trajectory.is_empty());

    // Same through the full coordinator: the outcome's tune fields are
    // all zero/empty with tune off.
    let env2 = SimEnv::new(cfg, &wl);
    let out = env2.run(&TransferSpec::fresh(env2.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.tune_epochs, 0);
    assert_eq!(out.tune_grows, 0);
    assert_eq!(out.tune_shrinks, 0);
    assert_eq!(out.tune_reverts, 0);
    assert_eq!(out.goodput_final, 0.0);
    assert!(out.tune_trajectory.is_empty());
    env2.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    let _ = std::fs::remove_dir_all(&env2.cfg.ft_dir);
}

#[test]
fn tune_on_negotiates_caps_and_reports_epochs() {
    // --tune from the pessimal defaults (window 1, batch 1, budgets 0):
    // the CONNECT advertises the raised caps so the applied values have
    // room to float, both tuner threads run (real time: for_tests'
    // time_scale 0.0 finishes before one epoch, so scale 1.0 + real
    // latency here), and the transfer still completes byte-verified.
    let mut cfg = Config::for_tests("autotune-on-smoke");
    cfg.tune = true;
    cfg.tune_epoch_ms = 1;
    cfg.time_scale = 1.0;
    cfg.net_latency_us = 200;
    let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(
        out.send_window,
        ftlads::tune::TUNE_WINDOW_CAP,
        "tune must negotiate the raised window cap"
    );
    assert!(out.tune_epochs >= 1, "no tuner epoch ever ticked");
    // With a healthy number of epochs the hill-climb must actually have
    // walked (the threshold keeps slow-CI short runs from flaking).
    if out.tune_epochs >= 12 {
        assert!(
            !out.tune_trajectory.is_empty(),
            "{} epochs but an empty trajectory",
            out.tune_epochs
        );
        assert!(out.goodput_final > 0.0);
    }
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}

#[test]
fn tune_with_multi_stream_lpt_sharding_completes() {
    // Tuner + LPT-sharded data plane: per-stream window rebalancing and
    // the sink's learned ost->stream ack routing must hold together
    // mid-walk, and the dataset still byte-verifies.
    let mut cfg = Config::for_tests("autotune-mstream");
    cfg.tune = true;
    cfg.tune_epoch_ms = 1;
    cfg.time_scale = 1.0;
    cfg.net_latency_us = 200;
    cfg.data_streams = 2;
    let wl = workload::big_workload(6, 8 * cfg.object_size); // 48 objects
    let env = SimEnv::new(cfg, &wl);
    let out = env.run(&TransferSpec::fresh(env.files.clone())).unwrap();
    assert!(out.completed, "{:?}", out.fault);
    assert_eq!(out.data_streams, 2);
    assert!(out.tune_epochs >= 1, "no tuner epoch ever ticked");
    env.verify_sink_complete().unwrap();
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
}
