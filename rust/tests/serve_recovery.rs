//! Crash-consistent serve daemon, end to end over real TCP: a sink
//! daemon with `serve_recover` on serves 4 concurrent tagged clients,
//! every client's leg is killed at a `FaultPlan` point mid-transfer and
//! the daemon torn down (the SIGKILL stand-in — only the disk state
//! survives: per-job FT logs, partial sink files, and the durable job
//! manifest). A restarted daemon over the same ft_dir replays the
//! manifest and hands every reconnecting client its recovered session;
//! each job finishes byte-exact within the §5.2.2 retransmit bound
//! (`resent <= total - logged`).
//!
//! Also pins the bounded `(fid, block)` dedup ledger: FILE_CLOSE
//! retires a file's ledger entries, so a completed session holds zero
//! of them no matter how many objects it moved.

use std::sync::Arc;

use ftlads::config::Config;
use ftlads::coordinator::serve::{serve_sink, serve_source};
use ftlads::coordinator::sink::SinkSession;
use ftlads::coordinator::source::SourceSession;
use ftlads::coordinator::TransferSpec;
use ftlads::fault::FaultPlan;
use ftlads::net::{channel, tcp, FaultController, Side};
use ftlads::pfs::sim::SimPfs;
use ftlads::pfs::Pfs;
use ftlads::workload;

/// Byte-exact sink check: every object of every file present, committed
/// and carrying the source's digest — the "zero duplicate / zero
/// corrupt pwrites" evidence.
fn verify_sink(cfg: &Config, source: &SimPfs, sink: &SimPfs, files: &[String]) {
    for name in files {
        let (_, meta) = sink
            .lookup(name)
            .unwrap_or_else(|| panic!("{name} missing at sink"));
        assert!(meta.committed, "{name} not committed");
        let objects = (meta.size + cfg.object_size - 1) / cfg.object_size;
        for b in 0..objects {
            let offset = b * cfg.object_size;
            let len = (meta.size - offset).min(cfg.object_size) as usize;
            let (got, _) = sink
                .written_digest(name, offset)
                .unwrap_or_else(|| panic!("{name} block {b} missing"));
            assert_eq!(
                got,
                source.expected_digest(name, offset, len),
                "{name} block {b} corrupt"
            );
        }
    }
}

/// Objects durable in `<ft_dir>/job-<id>`'s FT log.
fn logged_objects(cfg: &Config, id: u64) -> u64 {
    let mut ft = cfg.ft();
    ft.dir = cfg.ft_dir.join(format!("job-{id}"));
    ftlads::ftlog::recover::recover_all(&ft)
        .unwrap()
        .values()
        .map(|s| s.count() as u64)
        .sum()
}

#[test]
fn dedup_ledger_is_retired_on_file_close() {
    // 6 files x 8 objects through a fault-free session: before the
    // bounded ledger, the sink would end holding one `done` entry per
    // object (48); FILE_CLOSE now retires each file's entries, so a
    // completed session holds exactly zero — ledger memory is bounded
    // by OPEN files, not by transfer size.
    let cfg = Config::for_tests("serve-ledger-bound");
    let wl = workload::big_workload(6, 512 << 10);
    let source = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
    source.populate(&wl.as_tuples());
    let sink = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
    let files: Vec<String> = wl.files.iter().map(|f| f.name.clone()).collect();

    let (src_ep, snk_ep) = channel::pair(cfg.wire(), FaultController::unarmed());
    let node = SinkSession::new(&cfg, sink.clone() as Arc<dyn Pfs>, Arc::new(snk_ep))
        .spawn()
        .unwrap();
    let src = SourceSession::new(&cfg, source.clone() as Arc<dyn Pfs>, Arc::new(src_ep))
        .run(&TransferSpec::fresh(files.clone()))
        .unwrap();
    assert!(src.fault.is_none(), "{:?}", src.fault);
    let report = node.join();
    assert!(report.fault.is_none(), "{:?}", report.fault);
    assert_eq!(report.counters.objects_synced, 6 * 8, "every object moved");
    assert_eq!(
        report.ledger_blocks, 0,
        "closed files must not retain dedup-ledger entries"
    );
    verify_sink(&cfg, &source, &sink, &files);
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}

#[test]
fn tcp_daemon_kill_and_recover_four_clients() {
    let mut cfg = Config::for_tests("serve-recovery-tcp");
    cfg.serve_recover = true;
    cfg.serve_max_jobs = 4;
    let jobs = 4usize;

    // One dataset per job, all on the same PFS pair (the "disks" that
    // survive the daemon kill).
    let source = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
    let sink = Arc::new(SimPfs::new(cfg.layout(), cfg.ost_config(), cfg.seed));
    let mut job_files: Vec<Vec<String>> = Vec::new();
    for j in 0..jobs {
        let wl = workload::mixed_workload(4, 256 << 10, 80 + j as u64);
        let named: Vec<(String, u64)> = wl
            .files
            .iter()
            .map(|f| (format!("job{j}-{}", f.name), f.size))
            .collect();
        source.populate(&named);
        job_files.push(named.into_iter().map(|(n, _)| n).collect());
    }
    let totals: Vec<u64> = job_files
        .iter()
        .map(|files| {
            files
                .iter()
                .map(|n| {
                    let size = source.lookup(n).unwrap().1.size;
                    (size + cfg.object_size - 1) / cfg.object_size
                })
                .sum()
        })
        .collect();

    // Phase 1: four concurrent clients, every leg killed at its own
    // fault point (the daemon "dies" with all four jobs incomplete —
    // serve_sink returns once all four sessions ended, the listener
    // drops with it).
    let listener = tcp::listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sink_cfg = cfg.clone();
    let sink_pfs = sink.clone();
    let daemon = std::thread::spawn(move || {
        serve_sink(&sink_cfg, &listener, sink_pfs as Arc<dyn Pfs>, None, jobs).unwrap()
    });
    let specs: Vec<TransferSpec> = job_files
        .iter()
        .enumerate()
        .map(|(j, files)| {
            TransferSpec::fresh(files.clone()).with_fault(FaultPlan::at_fraction(
                0.35 + 0.1 * j as f64,
                Side::Source,
            ))
        })
        .collect();
    let results = serve_source(&cfg, addr, source.clone() as Arc<dyn Pfs>, specs).unwrap();
    for (job, report) in &results {
        let faulted = match report {
            Ok(r) => r.fault.is_some(),
            Err(_) => true,
        };
        assert!(faulted, "job {job} must die at its fault point");
    }
    let (_, stats1) = daemon.join().unwrap();
    assert_eq!(stats1.jobs_submitted, jobs as u64);
    assert_eq!(stats1.jobs_faulted, jobs as u64);
    assert_eq!(stats1.jobs_recovered, 0);
    // SUBMITTED + ADMITTED + FAULTED per job, all fsynced.
    assert!(stats1.manifest_records >= 3 * jobs as u64);

    // What survived the kill.
    let logged: Vec<u64> = (1..=jobs as u64).map(|id| logged_objects(&cfg, id)).collect();
    assert!(logged.iter().any(|&l| l > 0), "nothing durable before the kill");
    let replay = ftlads::ftlog::manifest::replay(&cfg.ft_dir).unwrap();
    assert_eq!(replay.incomplete().count(), jobs, "all jobs incomplete on disk");

    // Phase 2: restart the daemon over the same ft_dir and reconnect
    // the four clients (same tags, no fault plans). The manifest replay
    // hands each CONNECT its recovered session; `serve_recover` forces
    // resume on the source side, so only the complement is resent.
    let listener = tcp::listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let sink_cfg = cfg.clone();
    let sink_pfs = sink.clone();
    let daemon = std::thread::spawn(move || {
        serve_sink(&sink_cfg, &listener, sink_pfs as Arc<dyn Pfs>, None, jobs).unwrap()
    });
    let specs: Vec<TransferSpec> =
        job_files.iter().map(|files| TransferSpec::fresh(files.clone())).collect();
    let results = serve_source(&cfg, addr, source.clone() as Arc<dyn Pfs>, specs).unwrap();
    for (job, report) in &results {
        let r = report.as_ref().unwrap_or_else(|e| panic!("job {job}: {e:#}"));
        assert!(r.fault.is_none(), "job {job} resume: {:?}", r.fault);
        let i = (*job - 1) as usize;
        // §5.2.2 across the daemon kill, per job.
        assert!(
            r.counters.objects_sent <= totals[i] - logged[i],
            "job {job}: resent {} > total {} - logged {}",
            r.counters.objects_sent,
            totals[i],
            logged[i]
        );
    }
    let (reports2, stats2) = daemon.join().unwrap();
    assert_eq!(stats2.jobs_recovered, jobs as u64, "every CONNECT handed off");
    assert_eq!(stats2.jobs_submitted, 0, "no job counted as a fresh submission");
    assert_eq!(stats2.jobs_completed, jobs as u64);
    for (job, report) in &reports2 {
        let r = report.as_ref().unwrap_or_else(|e| panic!("sink job {job}: {e:#}"));
        assert!(r.fault.is_none(), "sink job {job}: {:?}", r.fault);
        assert_eq!(r.ledger_blocks, 0, "sink job {job} retains ledger entries");
    }

    // Byte-exact sinks: every file of every job present, committed,
    // digest-identical to the source — no duplicate or torn writes.
    for files in &job_files {
        verify_sink(&cfg, &source, &sink, files);
    }
    // The recovered daemon's manifest now ends every job COMPLETED.
    let replay = ftlads::ftlog::manifest::replay(&cfg.ft_dir).unwrap();
    assert_eq!(replay.incomplete().count(), 0, "recovery must close the story");
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
}
