//! Fault injection + resume — the paper's §5.2 story end to end:
//!
//!   1. start a transfer, kill the connection at 40 % of the data;
//!   2. inspect the FT logger state left on disk (the object-level
//!      progress record that offset checkpoints cannot express);
//!   3. resume: completed files skip via the sink metadata match,
//!      partially-transferred files send only their pending objects;
//!   4. inject a *second* fault mid-resume, resume again (logs seeded
//!      from recovery must survive repeated faults);
//!   5. verify the sink dataset byte-for-byte.
//!
//!     cargo run --release --example fault_and_resume

use ftlads::config::Config;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{recover, Mechanism, Method};
use ftlads::net::Side;
use ftlads::util::{fmt_bytes, fmt_duration};
use ftlads::workload;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.mechanism = Mechanism::File;
    cfg.method = Method::Bit64;
    cfg.ft_dir = std::env::temp_dir().join("ftlads-example-resume");
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);

    let wl = workload::big_workload(10, 4 << 20); // 10 files x 16 objects
    let env = SimEnv::new(cfg, &wl);
    let total_objects = wl.total_objects(env.cfg.object_size);
    println!(
        "dataset: {} files, {} total, {} objects\n",
        wl.file_count(),
        fmt_bytes(wl.total_bytes()),
        total_objects
    );

    // --- 1. fault at 40 % ---------------------------------------------
    println!("[1] transferring with a fault armed at 40% of payload...");
    let out = env.run(
        &TransferSpec::fresh(env.files.clone())
            .with_fault(FaultPlan::at_fraction(0.4, Side::Source)),
    )?;
    assert!(!out.completed);
    println!(
        "    fault hit after {} ({} of {} objects synced): {}",
        fmt_duration(out.elapsed),
        out.source.objects_synced,
        total_objects,
        out.fault.as_deref().unwrap_or("?"),
    );

    // --- 2. inspect logger state ---------------------------------------
    let recovered = recover::recover_all(&env.cfg.ft())?;
    println!(
        "\n[2] FT logger state on disk ({} in-flight files, completed files' logs deleted):",
        recovered.len()
    );
    for (name, set) in &recovered {
        println!(
            "    {name}: {:>3}/{} objects durable, pending {:?}{}",
            set.count(),
            set.total(),
            set.pending().iter().take(6).collect::<Vec<_>>(),
            if set.pending().len() > 6 { "..." } else { "" }
        );
    }

    // --- 3 + 4. resume, second fault, resume again ----------------------
    println!("\n[3] resuming with a second fault armed at 60%...");
    let out2 = env.run(
        &TransferSpec::resuming(env.files.clone())
            .with_fault(FaultPlan::at_fraction(0.6, Side::Source)),
    )?;
    if out2.completed {
        println!("    (second fault did not trigger — remainder was small)");
    } else {
        println!(
            "    second fault hit; {} objects skipped by resume, {} more synced",
            out2.source.objects_skipped_resume, out2.source.objects_synced
        );
        println!("\n[4] final resume...");
    }
    if !out2.completed {
        let out3 = env.run(&TransferSpec::resuming(env.files.clone()))?;
        assert!(out3.completed, "final resume failed: {:?}", out3.fault);
        println!(
            "    completed in {}: {} files skipped whole, {} objects skipped, {} retransmitted",
            fmt_duration(out3.elapsed),
            out3.source.files_skipped_resume,
            out3.source.objects_skipped_resume,
            out3.source.objects_sent
        );
    }

    // --- 5. verify -------------------------------------------------------
    env.verify_sink_complete()?;
    println!("\n[5] sink dataset verified: every object present with the correct digest");
    let leftovers = recover::recover_all(&env.cfg.ft())?;
    assert!(leftovers.is_empty(), "logs should be gone after completion");
    println!("    FT log directory clean (all logs deleted on completion)");
    let _ = std::fs::remove_dir_all(&env.cfg.ft_dir);
    Ok(())
}
