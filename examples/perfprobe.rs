use std::time::Instant;
fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let svc = ftlads::runtime::RuntimeService::start(&dir).unwrap();
    let h = svc.handle();
    let b = h.manifest.digest_batch; let w = h.manifest.object_words;
    let data = vec![7u32; b * w];
    // warmup
    for _ in 0..3 { h.execute_u32("digest", vec![data.clone()]).unwrap(); }
    // (a) clone + execute
    let t0 = Instant::now();
    for _ in 0..20 { h.execute_u32("digest", vec![data.clone()]).unwrap(); }
    println!("clone+execute: {:.3} ms", t0.elapsed().as_secs_f64()/20.0*1e3);
    // (b) alloc + zero cost
    let t0 = Instant::now();
    for _ in 0..20 { let v = vec![0u32; b*w]; std::hint::black_box(&v); }
    println!("alloc+zero 2M u32: {:.3} ms", t0.elapsed().as_secs_f64()/20.0*1e3);
    // (c) byte->u32 staging loop cost
    let bytes = vec![9u8; b*w*4];
    let t0 = Instant::now();
    for _ in 0..20 {
        let mut st = vec![0u32; b*w];
        for (i, c) in bytes.chunks_exact(4).enumerate() {
            st[i] = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        std::hint::black_box(&st);
    }
    println!("staging fill loop: {:.3} ms", t0.elapsed().as_secs_f64()/20.0*1e3);
}
