//! Quickstart: transfer a small dataset through FT-LADS and verify every
//! byte arrived.
//!
//!     cargo run --release --example quickstart
//!
//! What it shows:
//!   1. build a simulated Lustre pair (11 OSTs each, paper geometry),
//!   2. run a transfer with the universal logger + bit64 method,
//!   3. check the integrity ledger: all objects present, digests match.
//!
//! Pass `--disk` to use the real-file PFS backend (files written under a
//! temp directory) instead of the in-memory simulator.

use std::sync::Arc;

use ftlads::config::Config;
use ftlads::coordinator::{run_transfer, SimEnv, TransferSpec};
use ftlads::ftlog::{Mechanism, Method};
use ftlads::pfs::disk::DiskPfs;
use ftlads::pfs::{Pfs, StripeLayout};
use ftlads::util::{fmt_bytes, fmt_duration};
use ftlads::workload;

fn main() -> anyhow::Result<()> {
    let use_disk = std::env::args().any(|a| a == "--disk");

    let mut cfg = Config::default();
    cfg.mechanism = Mechanism::Universal;
    cfg.method = Method::Bit64;
    cfg.ft_dir = std::env::temp_dir().join("ftlads-quickstart-ftlog");
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);

    // 12 files x 2 MiB = 96 objects at the 256 KiB MTU.
    let wl = workload::big_workload(12, 2 << 20);
    println!(
        "quickstart: {} files, {} total, {} objects @ {} MTU, backend = {}",
        wl.file_count(),
        fmt_bytes(wl.total_bytes()),
        wl.total_objects(cfg.object_size),
        fmt_bytes(cfg.object_size),
        if use_disk { "disk" } else { "sim" },
    );

    if use_disk {
        // Real files: populate a source directory with synthetic data,
        // then move it through the full stack into a sink directory.
        let root = std::env::temp_dir().join("ftlads-quickstart-disk");
        let _ = std::fs::remove_dir_all(&root);
        let src_dir = root.join("src-staging");
        std::fs::create_dir_all(&src_dir)?;
        let mut rng = ftlads::testutil::Pcg32::new(42);
        for f in &wl.files {
            let mut data = vec![0u8; f.size as usize];
            rng.fill_bytes(&mut data);
            let flat = f.name.replace('/', "_");
            std::fs::write(src_dir.join(flat), data)?;
        }
        let layout = StripeLayout::paper();
        let source = DiskPfs::new(&root.join("source"), layout.clone(), cfg.ost_config())?;
        source.import_dir(&src_dir)?;
        let sink = DiskPfs::new(&root.join("sink"), layout, cfg.ost_config())?;
        let files = source.list();
        let source: Arc<dyn Pfs> = Arc::new(source);
        let sink_arc = Arc::new(sink);
        let sink_dyn: Arc<dyn Pfs> = sink_arc.clone();
        let out = run_transfer(
            &cfg,
            source.clone(),
            sink_dyn,
            &TransferSpec::fresh(files.clone()),
            None,
        )?;
        report(&out);
        // Byte-for-byte comparison of every file.
        for name in &files {
            let a = std::fs::read(root.join("source").join(name))?;
            let b = std::fs::read(root.join("sink").join(name))?;
            anyhow::ensure!(a == b, "content mismatch in {name}");
        }
        println!("disk backend: all {} files byte-identical at the sink", files.len());
        let _ = std::fs::remove_dir_all(&root);
    } else {
        let env = SimEnv::new(cfg, &wl);
        let out = env.run(&TransferSpec::fresh(env.files.clone()))?;
        report(&out);
        env.verify_sink_complete()?;
        println!("sim backend: integrity ledger verified for every object");
    }
    Ok(())
}

fn report(out: &ftlads::coordinator::TransferOutcome) {
    println!(
        "transfer {} in {}: {} payload, {:.1} MB/s, {} objects synced, \
         ft-log peak {}",
        if out.completed { "completed" } else { "FAILED" },
        fmt_duration(out.elapsed),
        fmt_bytes(out.payload_bytes),
        out.throughput_bytes_per_sec() / 1e6,
        out.source.objects_synced,
        fmt_bytes(out.log_space.peak_bytes),
    );
}
