//! End-to-end reproduction driver: exercises the full three-layer stack
//! on a real (scaled) workload and regenerates every figure of the
//! paper's evaluation section, printing paper-vs-measured for the
//! headline claims:
//!
//!   * FT data-transfer-time overhead < 1 %            (Figs 5, 6)
//!   * log space overhead KB-scale, bitbinary smallest  (Fig 7)
//!   * recovery time ≈ 10 % of transfer time, ~flat in
//!     the fault point; universal+bitbinary best        (Figs 8, 9, 10)
//!
//! It also runs one transfer with `integrity = pjrt`, proving the
//! compiled Pallas digest artifact sits on the sink's hot path (L1→L2→L3
//! composition), and one fault/resume cycle through that same stack.
//!
//!     cargo run --release --example reproduce_figures            # default scale
//!     FTLADS_BENCH_SCALE=quick cargo run --release --example reproduce_figures
//!
//! The per-figure tables are produced by the dedicated benches
//! (`cargo bench --bench fig5_big_overhead`, ...); this driver runs a
//! representative subset of each so one command tells the whole story.

use std::time::Duration;

use ftlads::bench_support::{
    measure_recovery_bbcp, measure_recovery_ftlads, print_table, run_case, BenchScale, Case,
};
use ftlads::config::Config;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{Mechanism, Method};
use ftlads::integrity::IntegrityMode;
use ftlads::net::Side;
use ftlads::runtime::RuntimeService;
use ftlads::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let scale = BenchScale::from_env();
    println!("FT-LADS end-to-end reproduction driver");
    println!(
        "scale: big {}x{}, small {}x{}, {} iteration(s)\n",
        scale.big_files,
        fmt_bytes(scale.big_file_size),
        scale.small_files,
        fmt_bytes(scale.small_file_size),
        scale.iterations
    );

    // ---- headline 1: FT overhead on transfer time (Figs 5a/6a) --------
    let wl_big = scale.big();
    let lads = run_case(&scale, &wl_big, Case::Lads, "rf-lads");
    let mut rows = Vec::new();
    let mut worst_overhead: f64 = f64::MIN;
    for case in [
        Case::Ft(Mechanism::File, Method::Bit64),
        Case::Ft(Mechanism::File, Method::Char),
        Case::Ft(Mechanism::Transaction, Method::Bit64),
        Case::Ft(Mechanism::Universal, Method::Bit64),
        Case::Ft(Mechanism::Universal, Method::Enc),
    ] {
        let out = run_case(&scale, &wl_big, case, &format!("rf-{}", case.label()));
        let ovh = (out.elapsed.as_secs_f64() / lads.elapsed.as_secs_f64() - 1.0) * 100.0;
        worst_overhead = worst_overhead.max(ovh);
        rows.push(vec![
            case.label(),
            format!("{:.3}", out.elapsed.as_secs_f64()),
            format!("{ovh:+.2}%"),
            format!("{:.1}", out.resources.cpu_percent),
            fmt_bytes(out.resources.peak_rss_bytes),
            fmt_bytes(out.log_space.peak_bytes),
        ]);
    }
    print_table(
        &format!(
            "Fig 5 (subset), big workload — LADS baseline {:.3}s",
            lads.elapsed.as_secs_f64()
        ),
        &["case", "time (s)", "vs LADS", "cpu %", "peak rss", "log peak"],
        &rows,
    );
    println!(
        "paper: FT overhead < 1%  |  measured worst case here: {worst_overhead:+.2}% \
         (run-to-run noise dominates at this scale)"
    );

    // ---- headline 2: space overhead (Fig 7) ----------------------------
    let mut rows = Vec::new();
    for mech in Mechanism::ALL_FT {
        let mut row = vec![mech.as_str().to_string()];
        for m in [
            Method::Char,
            Method::Int,
            Method::Enc,
            Method::Binary,
            Method::Bit8,
            Method::Bit64,
        ] {
            let out = run_case(
                &scale,
                &wl_big,
                Case::Ft(mech, m),
                &format!("rf7-{}-{}", mech.as_str(), m.as_str()),
            );
            row.push(fmt_bytes(out.log_space.peak_bytes));
        }
        rows.push(row);
    }
    print_table(
        "Fig 7, big workload: peak logger bytes",
        &["mechanism", "char", "int", "enc", "binary", "bit8", "bit64"],
        &rows,
    );
    println!("paper: bitbinary (bit8/bit64) smallest; everything KB-scale");

    // ---- headline 3: recovery time (Figs 8/10) -------------------------
    let mut rows = Vec::new();
    let mut file_bit64_rec = Duration::ZERO;
    let mut tt_ref = Duration::ZERO;
    for (label, case) in [
        ("LADS (restart)", Case::Lads),
        ("file/bit64", Case::Ft(Mechanism::File, Method::Bit64)),
        ("file/char", Case::Ft(Mechanism::File, Method::Char)),
        ("transaction/bit64", Case::Ft(Mechanism::Transaction, Method::Bit64)),
        ("universal/bit64", Case::Ft(Mechanism::Universal, Method::Bit64)),
    ] {
        let mut row = vec![label.to_string()];
        for &p in &[0.2, 0.8] {
            let r = measure_recovery_ftlads(&scale, &wl_big, case, p, "rf8");
            if label == "file/bit64" && p == 0.8 {
                file_bit64_rec = r.estimated_recovery();
                tt_ref = r.tt;
            }
            row.push(format!("{:.3}", r.estimated_recovery().as_secs_f64()));
        }
        rows.push(row);
    }
    let rb = measure_recovery_bbcp(&scale, &wl_big, 0.8, "rf8-bbcp");
    rows.push(vec![
        "bbcp".to_string(),
        "-".to_string(),
        format!("{:.3}", rb.estimated_recovery().as_secs_f64()),
    ]);
    print_table(
        "Fig 8/10 (subset), big workload: ER_t (s) at 20% / 80% fault",
        &["case", "ER@20%", "ER@80%"],
        &rows,
    );
    println!(
        "paper: recovery ≈10% of transfer time at any fault point  |  measured \
         file/bit64 @80%: {:.1}% of TT ({:.3}s / {:.3}s)",
        file_bit64_rec.as_secs_f64() / tt_ref.as_secs_f64().max(1e-9) * 100.0,
        file_bit64_rec.as_secs_f64(),
        tt_ref.as_secs_f64()
    );

    // ---- full-stack proof: PJRT integrity on the hot path --------------
    println!("\n=== three-layer composition: Pallas digest artifact on the sink hot path ===");
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if artifacts.join("manifest.json").exists() {
        let service = RuntimeService::start(&artifacts)?;
        let handle = service.handle();
        let mut cfg = Config::for_tests("rf-pjrt");
        cfg.integrity = IntegrityMode::Pjrt;
        cfg.object_size = handle.manifest.object_bytes as u64;
        cfg.rma_bytes = 64 * cfg.object_size as usize;
        cfg.time_scale = scale.time_scale;
        cfg.mechanism = Mechanism::Universal;
        cfg.method = Method::Bit64;
        let wl = ftlads::workload::big_workload(8, 4 * cfg.object_size);
        let env = SimEnv::new(cfg, &wl);
        // corrupt one write to prove the kernel is actually checking
        env.sink.inject_write_corruption(&env.files[3], 0);
        let out = env.run_with_runtime(
            &TransferSpec::fresh(env.files.clone())
                .with_fault(FaultPlan::at_fraction(0.55, Side::Source)),
            Some(handle.clone()),
        )?;
        assert!(!out.completed, "fault should trigger");
        let out2 = env.run_with_runtime(
            &TransferSpec::resuming(env.files.clone()),
            Some(handle),
        )?;
        assert!(out2.completed, "{:?}", out2.fault);
        env.verify_sink_complete()?;
        let caught = out.sink.objects_failed_verify + out2.sink.objects_failed_verify;
        println!(
            "pjrt integrity transfer: fault at 55% -> resume -> verified. \
             corrupted writes caught by the compiled Pallas kernel: {caught} \
             (objects skipped on resume: {})",
            out2.source.objects_skipped_resume
        );
    } else {
        println!("artifacts/ not built — run `make artifacts` for the PJRT leg");
    }

    println!("\ndriver complete. Full tables: cargo bench --bench fig5..fig10.");
    Ok(())
}
