//! Congested-OST scenario — the motivation of LADS itself (paper §2.1):
//! when some OSTs of the shared PFS are loaded by other tenants, a
//! layout/congestion-aware scheduler keeps the transfer moving on the
//! idle OSTs, while a file-sequential tool stalls whenever the current
//! file lives on a slow OST.
//!
//! This example loads 3 of the 11 source OSTs with an 8× service-time
//! multiplier and compares FT-LADS against the bbcp model on the same
//! dataset, then prints the per-OST service totals so the avoidance is
//! visible.
//!
//!     cargo run --release --example congested_ost

use ftlads::baseline::bbcp::{run_bbcp, BbcpConfig};
use ftlads::config::Config;
use ftlads::coordinator::{SimEnv, TransferSpec};
use ftlads::fault::FaultPlan;
use ftlads::ftlog::{Mechanism, Method};
use ftlads::pfs::ost::OstId;
use ftlads::pfs::Pfs;
use ftlads::util::{fmt_bytes, fmt_duration};
use ftlads::workload;

const LOADED_OSTS: [u32; 3] = [1, 4, 7];
const LOAD_FACTOR: f64 = 8.0;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.mechanism = Mechanism::Universal;
    cfg.method = Method::Bit64;
    cfg.ft_dir = std::env::temp_dir().join("ftlads-example-congestion");
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);

    // Mixed production-like sizes spread round-robin across the 11 OSTs.
    let wl = workload::big_workload(22, 2 << 20);
    println!(
        "dataset: {} files, {} — OSTs {:?} externally loaded {}x\n",
        wl.file_count(),
        fmt_bytes(wl.total_bytes()),
        LOADED_OSTS,
        LOAD_FACTOR
    );

    // --- FT-LADS ---------------------------------------------------------
    let env = SimEnv::new(cfg.clone(), &wl);
    for ost in LOADED_OSTS {
        env.source.ost_model().set_external_load(OstId(ost), LOAD_FACTOR);
    }
    let t_lads = env.run(&TransferSpec::fresh(env.files.clone()))?;
    assert!(t_lads.completed, "{:?}", t_lads.fault);
    env.verify_sink_complete()?;

    println!(
        "FT-LADS (layout+congestion aware): {}",
        fmt_duration(t_lads.elapsed)
    );
    println!("  source OST service totals (reads):");
    for i in 0..11u32 {
        let s = env.source.ost_model().stats(OstId(i));
        let marker = if LOADED_OSTS.contains(&i) { "  <-- loaded" } else { "" };
        println!(
            "    ost{i:<2} reads {:>4}  wait {:>7.1} ms  service {:>7.1} ms{marker}",
            s.reads,
            s.wait_ns as f64 / 1e6,
            s.service_ns as f64 / 1e6,
        );
    }

    // --- bbcp ------------------------------------------------------------
    let env_b = SimEnv::new(cfg.clone(), &wl);
    for ost in LOADED_OSTS {
        env_b.source.ost_model().set_external_load(OstId(ost), LOAD_FACTOR);
    }
    let bcfg = BbcpConfig::paper_defaults(&env_b.cfg);
    let t_bbcp = run_bbcp(
        &env_b.cfg,
        &bcfg,
        env_b.source.clone(),
        env_b.sink.clone(),
        &env_b.files,
        FaultPlan::none(),
    )?;
    assert!(t_bbcp.completed, "{:?}", t_bbcp.fault);
    println!(
        "\nbbcp (file-sequential)           : {}",
        fmt_duration(t_bbcp.elapsed)
    );

    let speedup = t_bbcp.elapsed.as_secs_f64() / t_lads.elapsed.as_secs_f64();
    println!(
        "\nFT-LADS is {speedup:.2}x faster under OST congestion \
         (paper §2.1: threads route around the slow OSTs; a sequential\n\
         tool is rate-limited by whichever OST the current file lives on)."
    );
    let _ = std::fs::remove_dir_all(&cfg.ft_dir);
    Ok(())
}
